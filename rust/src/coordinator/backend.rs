//! Decode execution backends behind the serving engine.
//!
//! [`DecodeServer`](super::server::DecodeServer) owns queueing, batching,
//! sampling, and retirement; *how* a batch of (token, position) rows is
//! stepped — and how per-sequence state is held — is a [`DecodeBackend`]:
//!
//! - [`PjrtBackend`]: the AOT path. Per-sequence dense state stacks are
//!   gathered into batched PJRT buffers, the compiled `decode_step`
//!   executes, states scatter back. Admission never backpressures (dense
//!   stacks are host `Vec`s) and prompts are ingested token-by-token.
//! - [`PooledBackend`]: the pure-Rust pooled engine. A **sequential**
//!   L-layer H-head log-linear attention LM (Mamba-2 or GDN transitions,
//!   see [`TransitionKind`]) whose per-(sequence, layer, head) Fenwick
//!   states live in a shared [`StatePool`]. Layer ℓ+1's q/k/v are
//!   projections of layer ℓ's per-token outputs
//!   ([`LayerProjection`]), so a decode step runs one pool-wide
//!   [`BatchedAdvance::advance_bucket`] pass plus one
//!   [`BatchedDecoder::read_batch`] block-sparse GEMM **per layer**
//!   (every (sequence, head) entry of the layer at once), threading the
//!   `(n, H·d_v)` hidden output into the next layer's projections, then
//!   one `O_last @ W_o^T` GEMM for the whole batch's logits. Prompts are
//!   ingested **chunkwise** through one
//!   [`LayerStack`](crate::prefill::LayerStack) per sequence
//!   ([`DecodeBackend::prefill_chunk`]) — the per-token chunk-output mode
//!   carries each layer's outputs into the next layer's chunk — and the
//!   first decode row flips the sequence to pooled decode states via the
//!   export bridge. Prompt **scoring** (per-token log-probs, no decode
//!   loop) rides the same stack: [`DecodeBackend::score_chunk`] returns a
//!   chunk's per-token logits from the last layer's chunk outputs, and
//!   [`DecodeBackend::score_tail`] token-steps the sub-chunk tail on
//!   Mat-backed states. Gates come from one [`GateTable`] per layer
//!   consulted by every path. [`DecodeBackend::admit`] reserves
//!   `layers · heads · blocks_for_steps(max_steps)` pool blocks per
//!   sequence and returns [`AdmitError::Exhausted`] when the pool can't
//!   hold another sequence. With
//!   [`PooledBackend::enable_prefix_cache`], finished prefills publish
//!   their chunk-boundary level states into a [`PrefixCache`] keyed on
//!   token-id prefixes; [`DecodeBackend::admit_prompt`] adopts the
//!   longest cached prefix (shared refcounted blocks, copy-on-write) so
//!   the server skips re-prefilling those tokens, and LRU eviction hands
//!   cached blocks back whenever live sequences need them.
//!
//! **The differential contract.** Every serving computation has a
//! per-sequence oracle replay on this type —
//! [`PooledBackend::oracle_decode_logits`] (chunkwise prefill span
//! re-ingested through an identical `LayerStack`, then per-token
//! per-layer recurrent [`FenwickState`] steps) and
//! [`PooledBackend::oracle_score_logprobs`] — built from the same
//! primitives in the same order, so the trace harness
//! (`coordinator::trace`) can assert serving output **bit-exact** against
//! them for any scheduling, batching, or interleaving.

use anyhow::{bail, Result};

use crate::prefill::bridge::export_prefill_head;
use crate::prefill::stack::{normalize_keys, LayerProjection, LayerStack};
use crate::prefill::Workspace;
use crate::runtime::{ModelHandle, Runtime};
use crate::state::batched_advance::bucket_feasible;
use crate::state::pool::{Precision, StatePool};
use crate::state::pooled::{blocks_for_steps, BatchedDecoder, PooledFenwickState};
use crate::state::prefix_cache::{BoundaryStates, PrefixCache};
use crate::state::sharded::ShardedStatePool;
use crate::state::{AdvanceJob, BatchedAdvance, FenwickState, GateTable, Transition};
use crate::tensor::{self, Mat};
use crate::util::threadpool::resident_pool;
use crate::util::Rng;

pub use crate::state::TransitionKind;

/// Backend-side handle for one admitted sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqSlot(pub usize);

/// Why admission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// No resources *right now* — retry once running sequences retire
    /// (the batcher keeps the request queued).
    Exhausted,
    /// The request can never fit this backend (e.g. needs more state
    /// blocks than the whole pool holds) — reject it.
    TooLarge,
}

/// One decode execution engine (state storage + step function).
pub trait DecodeBackend {
    /// Reserve resources for a sequence running at most `max_steps`
    /// decode steps; returns the slot to pass to [`DecodeBackend::step`].
    fn admit(&mut self, max_steps: usize) -> Result<SeqSlot, AdmitError>;

    /// Admit a generation sequence with its prompt visible to the
    /// backend, so backends with a prefix-state cache can reuse state
    /// computed for earlier prompts sharing a leading token run. Returns
    /// the slot plus the number of leading prompt tokens the backend's
    /// cached state already covers — the server must NOT feed those
    /// tokens again (neither as prefill chunks nor step rows). Default:
    /// plain [`DecodeBackend::admit`], nothing cached.
    fn admit_prompt(&mut self, max_steps: usize, prompt: &[i32]) -> Result<(SeqSlot, usize), AdmitError> {
        let _ = prompt;
        self.admit(max_steps).map(|slot| (slot, 0))
    }

    /// Release a sequence's resources.
    fn retire(&mut self, slot: SeqSlot);

    /// `(current, peak)` occupancy of the backend's admission-limited
    /// state store — pool blocks for the pooled backend, `(0, 0)` for
    /// backends without one. Sampled into `ServerStats` each step.
    fn pool_occupancy(&self) -> (usize, usize) {
        (0, 0)
    }

    /// The model's vocabulary size — the width of every logits row
    /// [`DecodeBackend::step`], [`DecodeBackend::score_chunk`], and
    /// [`DecodeBackend::score_tail`] return. The server validates step
    /// output against `rows.len() * vocab()` instead of *deriving* the
    /// width from `logits.len() / rows` — the derived form silently
    /// mis-splits rows whenever a backend returns a padded (or
    /// truncated) buffer, which is exactly the case a partially-filled
    /// bucket produces.
    fn vocab(&self) -> usize;

    /// Execute one decode step for `rows` of (slot, token, position) in a
    /// `bucket`-sized batch (`rows.len() <= bucket`; padding, if the
    /// backend needs fixed shapes, is backend-internal). Returns logits
    /// `(rows.len(), vocab)` row-major.
    fn step(&mut self, bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>>;

    /// Resident decode-state bytes right now (peak accounting).
    fn state_bytes(&self) -> usize;

    /// Chunk size for chunked prompt prefill; 0 = unsupported (the server
    /// then feeds prompts token-by-token through [`DecodeBackend::step`],
    /// the pre-prefill behavior).
    fn prefill_chunk_size(&self) -> usize {
        0
    }

    /// Ingest one full prompt chunk for `slot`: `tokens` are the prompt
    /// tokens at positions `pos .. pos + tokens.len()`, state-only (no
    /// logits — the prompt's final token goes through
    /// [`DecodeBackend::step`] to produce the first sample). Only valid
    /// before the sequence's first decode row, with
    /// `tokens.len() == prefill_chunk_size()` and chunk-aligned `pos`.
    fn prefill_chunk(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<()> {
        let _ = (slot, tokens, pos);
        bail!("this backend does not support chunked prefill")
    }

    /// Does this backend implement the prompt-scoring path
    /// ([`DecodeBackend::score_admit`] / [`DecodeBackend::score_chunk`] /
    /// [`DecodeBackend::score_tail`])?
    fn supports_scoring(&self) -> bool {
        false
    }

    /// Admit a scoring-only sequence: prompt ingestion and per-token
    /// logits, never a decode step. Release with
    /// [`DecodeBackend::retire`].
    fn score_admit(&mut self) -> Result<SeqSlot, AdmitError> {
        Err(AdmitError::TooLarge)
    }

    /// Ingest one full prompt chunk of a scoring sequence and return the
    /// chunk's per-token logits `(chunk, vocab)` row-major — row `i` is
    /// the next-token distribution after position `pos + i`, computed
    /// from the sequential stack's last-layer per-token chunk outputs.
    fn score_chunk(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<Vec<f32>> {
        let _ = (slot, tokens, pos);
        bail!("this backend does not support prompt scoring")
    }

    /// Token-step a scoring sequence's sub-chunk tail: `tokens` at
    /// positions `pos .. pos + tokens.len()`, returning their logits
    /// `(tokens.len(), vocab)`. May be called with an empty `tokens` to
    /// finalize a chunk-aligned prompt.
    fn score_tail(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<Vec<f32>> {
        let _ = (slot, tokens, pos);
        bail!("this backend does not support prompt scoring")
    }
}

// ---------------------------------------------------------------------------
// PJRT (AOT artifact) backend
// ---------------------------------------------------------------------------

/// The compiled-artifact backend: dense per-layer state stacks per
/// sequence, batched through the AOT `decode_step` executables.
pub struct PjrtBackend {
    model: ModelHandle,
    state_numels: Vec<usize>,
    dense_state_bytes_per_seq: usize,
    /// per-slot per-layer flat states (None = free slot)
    slots: Vec<Option<Vec<Vec<f32>>>>,
    free_slots: Vec<usize>,
}

impl PjrtBackend {
    /// Compile the decode executables for every bucket up front.
    pub fn new(rt: &Runtime, mut model: ModelHandle, buckets: &[usize]) -> Result<PjrtBackend> {
        for &b in buckets {
            model.ensure_decode(rt, b)?;
        }
        let state_numels: Vec<usize> = model
            .manifest
            .state_shapes
            .iter()
            .map(|s| s.iter().product())
            .collect();
        let dense = state_numels.iter().sum::<usize>() * 4;
        Ok(PjrtBackend {
            model,
            state_numels,
            dense_state_bytes_per_seq: dense,
            slots: Vec::new(),
            free_slots: Vec::new(),
        })
    }

    pub fn model(&self) -> &ModelHandle {
        &self.model
    }
}

impl DecodeBackend for PjrtBackend {
    fn vocab(&self) -> usize {
        self.model.manifest.cfg("vocab")
    }

    fn admit(&mut self, _max_steps: usize) -> Result<SeqSlot, AdmitError> {
        let states: Vec<Vec<f32>> = self.state_numels.iter().map(|&n| vec![0.0f32; n]).collect();
        let idx = match self.free_slots.pop() {
            Some(i) => {
                self.slots[i] = Some(states);
                i
            }
            None => {
                self.slots.push(Some(states));
                self.slots.len() - 1
            }
        };
        Ok(SeqSlot(idx))
    }

    fn retire(&mut self, slot: SeqSlot) {
        assert!(self.slots[slot.0].take().is_some(), "retire of free slot");
        self.free_slots.push(slot.0);
    }

    fn step(&mut self, bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 || n > bucket {
            bail!("bad batch: {n} rows for bucket {bucket}");
        }
        let layers = self.state_numels.len();
        // gather into the fixed (bucket, ...) shapes the artifact expects
        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        let mut batched: Vec<Vec<f32>> = self
            .state_numels
            .iter()
            .map(|&numel| vec![0.0f32; bucket * numel])
            .collect();
        for (i, &(slot, tok, p)) in rows.iter().enumerate() {
            tokens[i] = tok;
            pos[i] = p;
            let st = self.slots[slot.0].as_ref().expect("live slot");
            for (l, layer) in st.iter().enumerate() {
                let numel = self.state_numels[l];
                batched[l][i * numel..(i + 1) * numel].copy_from_slice(layer);
            }
        }
        let mut logits = self.model.decode_step(bucket, &mut batched, &tokens, &pos)?;
        // scatter back
        for (i, &(slot, _, _)) in rows.iter().enumerate() {
            let st = self.slots[slot.0].as_mut().expect("live slot");
            for l in 0..layers {
                let numel = self.state_numels[l];
                st[l].copy_from_slice(&batched[l][i * numel..(i + 1) * numel]);
            }
        }
        // drop padding rows in place — no copy in the full-bucket case.
        // The row width is the manifest's, never derived from the buffer:
        // a ragged artifact output must fail loudly here, not mis-split.
        let vocab = self.vocab();
        if logits.len() != bucket * vocab {
            bail!("decode_step returned {} floats for bucket {bucket} × vocab {vocab}", logits.len());
        }
        logits.truncate(n * vocab);
        Ok(logits)
    }

    fn state_bytes(&self) -> usize {
        self.slots.iter().flatten().count() * self.dense_state_bytes_per_seq
    }
}

// ---------------------------------------------------------------------------
// Pooled pure-Rust backend
// ---------------------------------------------------------------------------

/// A scoring-only sequence's backend state: the sequential prefill stack
/// while chunks stream in (absent when chunked prefill is disabled),
/// then Mat-backed per-(layer, head) token states for the sub-chunk tail
/// — scoring never touches the pool, so it can never backpressure
/// decode admission.
struct ScoreSeq {
    stack: Option<LayerStack>,
    tail: Vec<FenwickState>,
}

/// Reusable scratch for [`PooledBackend::token_step_layers`] — callers
/// hold one across their token loop so the per-token recurrent path
/// (scoring tails, oracle replays) allocates nothing per token beyond
/// the returned logits row.
#[derive(Default)]
struct TokenScratch {
    o_prev: Vec<f32>,
    o_cur: Vec<f32>,
    q_rows: Vec<f32>,
    k_rows: Vec<f32>,
    v_rows: Vec<f32>,
}

impl TokenScratch {
    /// Size every buffer for an H-head model (cleared to zero; layer 0
    /// overwrites q/k/v fully and o_prev is never read before the first
    /// layer swap, so contents cannot leak between tokens).
    fn fit(&mut self, heads: usize, dk: usize, dv: usize) {
        for (buf, n) in [
            (&mut self.o_prev, heads * dv),
            (&mut self.o_cur, heads * dv),
            (&mut self.q_rows, heads * dk),
            (&mut self.k_rows, heads * dk),
            (&mut self.v_rows, heads * dv),
        ] {
            buf.clear();
            buf.resize(n, 0.0);
        }
    }
}

/// One shard's private execution engine: its own batched advance/read
/// planners (their scratch is not shareable across concurrent jobs) plus
/// the per-shard row index list and input/output buffers a shard job
/// works in. `o` is the **pipeline register**: in pipelined mode it
/// carries layer ℓ's per-token outputs across the
/// [`LayerProjection`] boundary into layer ℓ+1's projections without
/// ever leaving the shard's job.
#[derive(Default)]
struct ShardEngine {
    adv: BatchedAdvance,
    dec: BatchedDecoder,
    /// bucket row indices pinned to this shard (rebuilt every step,
    /// bucket order — so per-shard outputs scatter back positionally)
    rows: Vec<usize>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    o: Vec<f32>,
}

/// One admitted sequence's backend-side state. Decode states are
/// layer-major, head-minor: index `l · heads + h`.
enum SeqState {
    /// generation prompt streaming chunks through the sequential stack;
    /// `tokens` records the chunk-fed prefix so far — the key the
    /// prefix cache stores the exported boundary under
    Prefilling { stack: LayerStack, tokens: Vec<i32> },
    /// pool-backed decode states (flipped by the export bridge on the
    /// first decode row)
    Decoding(Vec<PooledFenwickState>),
    /// prompt-scoring sequence (never decodes)
    Scoring(ScoreSeq),
}

/// Pure-Rust pooled decode backend: a fixed-weight **sequential** L-layer
/// H-head log-linear attention LM whose decode states live in a shared
/// [`StatePool`] and whose prompts ingest chunkwise through one
/// [`LayerStack`] per sequence. Exists to serve real token traffic
/// through the batched Fenwick engines without PJRT — the
/// scheduler/backpressure testbed and the bench engine for
/// `decode_batched` / `prefill_throughput` / `decode_latency`.
///
/// **Model layout (sequential stack).** Layer 0 reads per-head q/k/v
/// token embeddings (keys L2-normalized). Layer `ℓ ≥ 1` reads
/// *projections* of layer `ℓ−1`'s per-token output `o ∈ R^{H·d_v}`
/// ([`LayerProjection`]; projected keys re-normalized per token by the
/// shared [`normalize_keys`]). Each layer has its own [`GateTable`]
/// (α/β/λ schedules, optionally per-head) and per-(sequence, head)
/// Fenwick level states in the one shared pool. Logits are one
/// `O_last @ W_o^T` GEMM against the `(vocab, H·d_v)` output head — the
/// last layer's hidden output, not a concat of parallel branches. A
/// single-layer config draws exactly the same weights as the
/// pre-sequential backend (same RNG order), so L = 1 trajectories are
/// preserved bit-for-bit.
///
/// **Step structure.** A decode step loops layers sequentially; per
/// layer it runs exactly two batched passes over the bucket's `n · H`
/// (sequence, head) entries — one pool-wide
/// [`BatchedAdvance::advance_bucket`] (merge + transition + sentinel
/// write as slab dispatches) and one [`BatchedDecoder::read_batch`]
/// block-sparse GEMM — then two or three `(n, H·d)` projection GEMMs
/// carry the hidden output into the next layer's inputs. Entry order
/// (sequence-major, head) makes the read output buffer both the next
/// layer's projection operand and the final logits GEMM's left operand
/// with no reshuffle.
pub struct PooledBackend {
    pub dk: usize,
    pub dv: usize,
    pub vocab: usize,
    pub heads: usize,
    pub layers: usize,
    kind: TransitionKind,
    /// layer-0 per-head query/key/value token embeddings,
    /// (vocab, dk|dk|dv) each; keys L2-normalized
    eq: Vec<Mat>,
    ek: Vec<Mat>,
    ev: Vec<Mat>,
    /// inter-layer input projections, one per layer transition (L−1)
    projs: Vec<LayerProjection>,
    /// output head, (vocab, heads·dv): logits = O_last @ W_o^T
    wo: Mat,
    /// per-layer position-dependent α/β/λ — the one gate source for
    /// prefill, decode, AND scoring
    gates: Vec<GateTable>,
    /// chunked-prefill chunk size (power of two; 0 disables)
    prefill_chunk: usize,
    /// the serving substrate: per-worker [`StatePool`] shards (one by
    /// default — the unsharded path, bit-for-bit), each optionally
    /// carrying its own prefix-state cache
    /// ([`PooledBackend::enable_prefix_cache`]). Sequences pin to one
    /// shard at admission; see docs/SHARDING.md.
    pool: ShardedStatePool,
    slots: Vec<Option<SeqState>>,
    free_slots: Vec<usize>,
    /// blocks reserved per live slot (admission accounting)
    reserved: Vec<usize>,
    /// which shard each slot's states live in (scoring slots: 0, unused)
    shard_of: Vec<usize>,
    /// run the decode step as one full-stack job per shard (the pipeline
    /// register mode) instead of the per-layer barrier
    pipelined: bool,
    /// one execution engine per shard (index-aligned with the pool's
    /// shards)
    engines: Vec<ShardEngine>,
    /// ONE prefill scratch workspace shared by every sequence's stack
    /// (the ROADMAP shared-workspace item): resident prefill scratch no
    /// longer scales with concurrent prompts
    ws: Workspace,
    // step workspaces (reused across steps; logits are allocated per
    // step because the trait returns an owned Vec)
    q_rows: Vec<f32>,
    k_rows: Vec<f32>,
    v_rows: Vec<f32>,
    o_buf: Vec<f32>,
    // prefill gather workspaces (the stacked per-head layer-0 q/k/v
    // embedding rows for one chunk)
    qc_buf: Vec<f32>,
    kc_buf: Vec<f32>,
    vc_buf: Vec<f32>,
}

impl PooledBackend {
    /// Single-layer single-head backend with the default gates and a
    /// 16-token prefill chunk. `pool_blocks` bounds resident decode
    /// memory: admission reserves
    /// `layers · heads · blocks_for_steps(max_steps)` blocks per sequence
    /// against it.
    pub fn new(vocab: usize, dk: usize, dv: usize, pool_blocks: usize, seed: u64) -> PooledBackend {
        PooledBackend::with_config(vocab, 1, dk, dv, 16, pool_blocks, seed)
    }

    /// Single-layer Mamba-2 backend: `heads` attention heads and a
    /// `prefill_chunk`-token chunkwise prefill path (0 disables chunked
    /// prefill; the server then feeds prompts token-by-token).
    pub fn with_config(
        vocab: usize,
        heads: usize,
        dk: usize,
        dv: usize,
        prefill_chunk: usize,
        pool_blocks: usize,
        seed: u64,
    ) -> PooledBackend {
        PooledBackend::with_model_config(
            vocab,
            1,
            heads,
            TransitionKind::Mamba2,
            dk,
            dv,
            prefill_chunk,
            pool_blocks,
            seed,
        )
    }

    /// Fully-configured backend: a sequential stack of `layers` layers of
    /// `heads` heads each, under the `kind` state transition (see the
    /// type docs for the model layout). A single-layer config reproduces
    /// the pre-sequential backend exactly (same RNG draws, same weights,
    /// same trajectories).
    #[allow(clippy::too_many_arguments)]
    pub fn with_model_config(
        vocab: usize,
        layers: usize,
        heads: usize,
        kind: TransitionKind,
        dk: usize,
        dv: usize,
        prefill_chunk: usize,
        pool_blocks: usize,
        seed: u64,
    ) -> PooledBackend {
        assert!(layers >= 1, "at least one layer");
        assert!(heads >= 1, "at least one head");
        assert!(
            prefill_chunk == 0 || prefill_chunk.is_power_of_two(),
            "prefill chunk must be a power of two (or 0 to disable)"
        );
        let mut rng = Rng::new(seed);
        let mut eq = Vec::with_capacity(heads);
        let mut ek = Vec::with_capacity(heads);
        let mut ev = Vec::with_capacity(heads);
        for _ in 0..heads {
            eq.push(Mat::randn(vocab, dk, 1.0 / (dk as f32).sqrt(), &mut rng));
            let mut k = Mat::randn(vocab, dk, 1.0, &mut rng);
            normalize_keys(&mut k.data, dk);
            ek.push(k);
            ev.push(Mat::randn(vocab, dv, 1.0, &mut rng));
        }
        let projs: Vec<LayerProjection> =
            (1..layers).map(|_| LayerProjection::random(heads, dk, dv, &mut rng)).collect();
        let wo = Mat::randn(vocab, heads * dv, 1.0 / ((heads * dv) as f32).sqrt(), &mut rng);
        // default schedule per layer: fixed α, λ^(l) = 2^-l — coarser
        // levels matter less; wide enough for any practical position
        // (clamped past the table by level_weight)
        let gates = GateTable::fixed(0.97, (0..24).map(|l| 0.5f32.powi(l)).collect());
        PooledBackend {
            dk,
            dv,
            vocab,
            heads,
            layers,
            kind,
            eq,
            ek,
            ev,
            projs,
            wo,
            gates: vec![gates; layers],
            prefill_chunk,
            pool: ShardedStatePool::new(dk * dv, pool_blocks, 1),
            slots: Vec::new(),
            free_slots: Vec::new(),
            reserved: Vec::new(),
            shard_of: Vec::new(),
            pipelined: false,
            engines: vec![ShardEngine::default()],
            ws: Workspace::new(),
            q_rows: Vec::new(),
            k_rows: Vec::new(),
            v_rows: Vec::new(),
            o_buf: Vec::new(),
            qc_buf: Vec::new(),
            kc_buf: Vec::new(),
            vc_buf: Vec::new(),
        }
    }

    /// The sharded state pool (inspection: aggregate in_use/peak/capacity
    /// plus per-shard views).
    pub fn pool(&self) -> &ShardedStatePool {
        &self.pool
    }

    /// Re-shard the serving substrate into `n` independent pools of
    /// `capacity() / n` blocks each, with per-shard engines (and, when
    /// prefix caching was enabled, per-shard caches — cache *contents*
    /// do not survive, block ids are shard-local). Only legal while no
    /// sequence is resident and no pool block is live: re-sharding moves
    /// the ownership boundary every existing handle was pinned under.
    pub fn set_shards(&mut self, n: usize) {
        assert!(n >= 1, "at least one shard");
        assert!(
            self.slots.iter().all(|s| s.is_none()),
            "set_shards with live sequences resident"
        );
        let cache_enabled = self.pool.cache_enabled();
        self.pool.clear_caches();
        assert_eq!(self.pool.in_use(), 0, "set_shards with pool blocks live");
        let per = self.pool.capacity() / n;
        assert!(per >= 1, "pool capacity {} cannot split into {n} shards", self.pool.capacity());
        self.pool = ShardedStatePool::new(self.dk * self.dv, per, n);
        if cache_enabled {
            self.pool.enable_prefix_cache(self.prefill_chunk);
        }
        self.engines = (0..n).map(|_| ShardEngine::default()).collect();
    }

    /// Switch the serving substrate's storage precision (docs/PRECISION.md):
    /// [`Precision::F32`] (the default, bit-exact with the oracle replay)
    /// or [`Precision::Bf16`] (state-pool bytes per sequence halved;
    /// logits match the f32 oracle within the documented relative-error
    /// bound, not bitwise). Rebuilds every shard's pool at the same
    /// geometry, so — like [`PooledBackend::set_shards`] — it is only
    /// legal while no sequence is resident and no pool block is live;
    /// cache *contents* do not survive (cached block payloads are stored
    /// at pool precision, so entries from one mode must not seed the
    /// other).
    pub fn set_precision(&mut self, precision: Precision) {
        assert!(
            self.slots.iter().all(|s| s.is_none()),
            "set_precision with live sequences resident"
        );
        let cache_enabled = self.pool.cache_enabled();
        self.pool.clear_caches();
        assert_eq!(self.pool.in_use(), 0, "set_precision with pool blocks live");
        let (n, per) = (self.pool.n_shards(), self.pool.shard_capacity());
        self.pool = ShardedStatePool::with_precision(self.dk * self.dv, per, n, precision);
        if cache_enabled {
            self.pool.enable_prefix_cache(self.prefill_chunk);
        }
    }

    /// The serving substrate's storage precision.
    pub fn precision(&self) -> Precision {
        self.pool.precision()
    }

    /// Switch the decode step between the per-layer barrier (off, the
    /// default) and the per-shard full-stack pipeline (on): each shard's
    /// job runs all L layers over its rows, carrying the layer-boundary
    /// output buffer through the [`LayerProjection`] registers without
    /// re-synchronizing with other shards between layers. Bit-exact
    /// either way (see docs/SHARDING.md for the argument).
    pub fn set_pipelined(&mut self, on: bool) {
        self.pipelined = on;
    }

    /// Is the per-shard full-stack pipeline mode on?
    pub fn pipelined(&self) -> bool {
        self.pipelined
    }

    /// The state-transition family this backend's layers run.
    pub fn transition_kind(&self) -> TransitionKind {
        self.kind
    }

    /// Resident bytes of the ONE shared prefill scratch workspace (the
    /// shared-workspace item's metric: this is what each additional
    /// concurrent prompt no longer allocates).
    pub fn workspace_bytes(&self) -> usize {
        self.ws.bytes()
    }

    /// Install a position-dependent gate schedule (per-token and/or
    /// per-head α/β/λ) on **every** layer. All three ingestion paths —
    /// chunkwise prefill, pooled decode, prompt scoring — read it, so
    /// they cannot drift. Only meaningful before traffic runs.
    pub fn set_gates(&mut self, gates: GateTable) {
        self.gates = vec![gates; self.layers];
        self.invalidate_prefix_cache();
    }

    /// Install one layer's gate schedule (per-layer gate tables).
    pub fn set_layer_gates(&mut self, layer: usize, gates: GateTable) {
        self.gates[layer] = gates;
        self.invalidate_prefix_cache();
    }

    /// Turn on the cross-request prefix-state cache: later admissions
    /// whose prompt shares a chunk-aligned leading token run with an
    /// earlier prompt adopt that prompt's exported boundary states
    /// (refcounted pool blocks, copy-on-write) instead of recomputing
    /// the prefill. Cache entries are evicted LRU whenever the pool
    /// needs blocks for live sequences, so enabling it never shrinks
    /// effective serving capacity. Requires chunked prefill.
    pub fn enable_prefix_cache(&mut self) {
        assert!(self.prefill_chunk > 0, "prefix cache requires chunked prefill");
        self.pool.enable_prefix_cache(self.prefill_chunk);
    }

    /// Drop every cache entry (all shards), releasing block refcounts
    /// back to the pools. Caches stay enabled (future prompts repopulate
    /// them).
    pub fn clear_prefix_cache(&mut self) {
        self.invalidate_prefix_cache();
    }

    /// Shard 0's prefix cache, if caching is enabled (inspection:
    /// entries/blocks held — exact on the default single-shard config;
    /// use [`ShardedStatePool::cache_blocks_held`] via
    /// [`PooledBackend::pool`] for multi-shard aggregates).
    pub fn prefix_cache(&self) -> Option<&PrefixCache> {
        self.pool.cache(0)
    }

    /// Cached states are keyed purely on token ids — valid only while
    /// the weights and gate tables are fixed. Gate swaps call this.
    fn invalidate_prefix_cache(&mut self) {
        self.pool.clear_caches();
        self.debug_assert_no_block_leaks();
    }

    /// Debug-build leak canary: every allocated pool block must be
    /// reachable from an owner the backend knows about — a live decoding
    /// sequence's level slots or a prefix-cache entry. Shared blocks
    /// (cache + adopters) collapse in the set union, so the reachable set's
    /// size must equal `pool.in_use()` exactly; a mismatch means a retain
    /// without a release (leak) or a release the accounting missed. Runs
    /// at the two points ownership is surrendered wholesale — sequence
    /// retirement and cache invalidation — where a drifted refcount would
    /// otherwise fossilize into permanently-lost capacity.
    #[cfg(debug_assertions)]
    fn debug_assert_no_block_leaks(&self) {
        // per shard, not pooled: BlockIds are shard-local (each shard
        // numbers from zero), so a global set union would alias blocks
        // across shards and hide leaks
        for s in 0..self.pool.n_shards() {
            let mut owned = std::collections::BTreeSet::new();
            for (idx, state) in self.slots.iter().enumerate() {
                let Some(SeqState::Decoding(seqs)) = state else { continue };
                if self.shard_of[idx] != s {
                    continue;
                }
                for seq in seqs {
                    owned.extend(seq.level_blocks().into_iter().map(|(_, id)| id.0));
                }
            }
            if let Some(cache) = self.pool.cache(s) {
                owned.extend(cache.held_block_ids().into_iter().map(|id| id.0));
            }
            debug_assert_eq!(
                owned.len(),
                self.pool.shard(s).in_use(),
                "shard {s} leak canary: {} blocks allocated but only {} reachable from \
                 live sequences + prefix cache",
                self.pool.shard(s).in_use(),
                owned.len()
            );
        }
    }

    #[cfg(not(debug_assertions))]
    fn debug_assert_no_block_leaks(&self) {}

    /// The gate schedule currently in force (layer 0's; see
    /// [`PooledBackend::layer_gates`] for the rest).
    pub fn gates(&self) -> &GateTable {
        &self.gates[0]
    }

    /// One layer's gate schedule.
    pub fn layer_gates(&self, layer: usize) -> &GateTable {
        &self.gates[layer]
    }

    /// Number of sequences currently mid-prefill (stack states resident
    /// outside the pool).
    pub fn prefilling(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|s| matches!(s, SeqState::Prefilling { .. }))
            .count()
    }

    /// Number of scoring sequences currently resident.
    pub fn scoring(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|s| matches!(s, SeqState::Scoring(_)))
            .count()
    }

    /// Flip a prefilling slot to decode mode: seal the stack at its chunk
    /// boundary and export every (layer, head) into pool blocks through
    /// the bridge. No-op for slots already decoding.
    fn ensure_decoding(&mut self, slot: SeqSlot) -> Result<()> {
        match &self.slots[slot.0] {
            Some(SeqState::Decoding(_)) => return Ok(()),
            Some(SeqState::Scoring(_)) => bail!("decode step for a scoring slot"),
            _ => {}
        }
        let Some(SeqState::Prefilling { mut stack, tokens }) = self.slots[slot.0].take() else {
            bail!("step row for a free slot");
        };
        stack.finish();
        // everything this sequence exports (and publishes) lives in the
        // shard it was pinned to at admission
        let (pool, mut cache) = self.pool.pair_mut(self.shard_of[slot.0]);
        let mut seqs = Vec::with_capacity(self.layers * self.heads);
        'export: for l in 0..self.layers {
            for h in 0..self.heads {
                loop {
                    match export_prefill_head(stack.engine(l), h, pool) {
                        Ok(s) => {
                            seqs.push(s);
                            break;
                        }
                        Err(_) => {
                            // cache-held blocks are the only occupancy
                            // beyond admission reservations — evict and
                            // retry before declaring a reservation bug
                            let evicted = match cache.as_deref_mut() {
                                Some(c) => c.evict_lru(pool),
                                None => false,
                            };
                            if !evicted {
                                break 'export;
                            }
                        }
                    }
                }
            }
        }
        if seqs.len() != self.layers * self.heads {
            // roll back the states already exported; unreachable under
            // admission reservation once the cache is drained, so
            // surface loudly
            for mut s in seqs {
                s.release(pool);
            }
            bail!("state pool exhausted during prefill export (reservation bug?)");
        }
        // publish the chunk-boundary states under the fed-token key:
        // insert only retains block handles (rc +1 each), so the blocks
        // outlive this sequence's retire and seed later admissions
        if !tokens.is_empty() {
            if let Some(cache) = cache {
                let states: BoundaryStates = seqs.iter().map(|s| s.level_blocks()).collect();
                cache.insert(&tokens, &states, pool);
            }
        }
        self.slots[slot.0] = Some(SeqState::Decoding(seqs));
        Ok(())
    }

    /// Gather one chunk's layer-0 inputs — the stacked per-head
    /// `(H, C, d)` q/k/v embedding rows — into the caller's buffers
    /// (cleared first). THE one gather for the serving prefill path
    /// ([`DecodeBackend::prefill_chunk`]), the scoring path
    /// ([`DecodeBackend::score_chunk`]), and both oracle replays, so all
    /// of them ingest bitwise-identical stack inputs by construction.
    fn gather_chunk_inputs(
        &self,
        tokens: &[i32],
        qc: &mut Vec<f32>,
        kc: &mut Vec<f32>,
        vc: &mut Vec<f32>,
    ) {
        qc.clear();
        kc.clear();
        vc.clear();
        for h in 0..self.heads {
            for &tok in tokens {
                let ti = tok_index(tok, self.vocab);
                qc.extend_from_slice(self.eq[h].row(ti));
                kc.extend_from_slice(self.ek[h].row(ti));
                vc.extend_from_slice(self.ev[h].row(ti));
            }
        }
    }

    /// The chunkwise-prefill position boundary for a `prompt_len`-token
    /// prompt: the server ingests full chunks while at least one chunk
    /// *plus the final prompt token the decode step needs* remains, so
    /// prefill covers positions `[0, boundary)` and the decode step feeds
    /// `[boundary, …)`. Scoring uses the same boundary, so score-path
    /// tail logits are bit-exact with the decode rows the same prompt
    /// would produce.
    pub fn prefill_boundary(&self, prompt_len: usize) -> usize {
        let c = self.prefill_chunk;
        let mut pe = 0;
        if c > 0 {
            while pe + c < prompt_len {
                pe += c;
            }
        }
        pe
    }

    /// One token through the sequential stack on Mat-backed states — the
    /// per-token, per-layer recurrent form shared by the decode oracle
    /// replay and the scoring tail. Bit-identical to the pooled decode
    /// step for the same inputs: the advance/read reduce to the same
    /// primitives ([`crate::state::update::advance_levels`] /
    /// `level_read_acc`), the projections run the same `gemm_nt` kernel
    /// (row-batched GEMMs are bit-exact per row), and the keys normalize
    /// through the same [`normalize_keys`]. Callers hold one
    /// [`TokenScratch`] across their token loop so per-token work stays
    /// allocation-free except the returned logits row.
    fn token_step_layers(
        &self,
        scratch: &mut TokenScratch,
        states: &mut [FenwickState],
        tok: i32,
        pos: usize,
    ) -> Vec<f32> {
        let (layers, heads, dk, dv, vocab) =
            (self.layers, self.heads, self.dk, self.dv, self.vocab);
        debug_assert_eq!(states.len(), layers * heads);
        let ti = tok_index(tok, vocab);
        scratch.fit(heads, dk, dv);
        let TokenScratch { o_prev, o_cur, q_rows, k_rows, v_rows } = scratch;
        for l in 0..layers {
            if l == 0 {
                for h in 0..heads {
                    q_rows[h * dk..(h + 1) * dk].copy_from_slice(self.eq[h].row(ti));
                    k_rows[h * dk..(h + 1) * dk].copy_from_slice(self.ek[h].row(ti));
                    v_rows[h * dv..(h + 1) * dv].copy_from_slice(self.ev[h].row(ti));
                }
            } else {
                let p = &self.projs[l - 1];
                tensor::gemm_nt_into(1, heads * dv, heads * dk, o_prev, &p.wq.data, q_rows, false);
                tensor::gemm_nt_into(1, heads * dv, heads * dk, o_prev, &p.wk.data, k_rows, false);
                normalize_keys(k_rows, dk);
                tensor::gemm_nt_into(1, heads * dv, heads * dv, o_prev, &p.wv.data, v_rows, false);
            }
            for h in 0..heads {
                let k = &k_rows[h * dk..(h + 1) * dk];
                let alpha = self.gates[l].alpha_h(h, pos);
                let (write_scale, tr) = match self.kind {
                    TransitionKind::Mamba2 => (1.0, Transition::Decay(alpha)),
                    TransitionKind::Gdn => {
                        let beta = self.gates[l].beta_h(h, pos);
                        (beta, Transition::GatedHouseholder { alpha, beta, k })
                    }
                };
                let o = states[l * heads + h].step(
                    &q_rows[h * dk..(h + 1) * dk],
                    k,
                    &v_rows[h * dv..(h + 1) * dv],
                    write_scale,
                    tr,
                    self.gates[l].lambda_h(h, pos),
                );
                o_cur[h * dv..(h + 1) * dv].copy_from_slice(&o);
            }
            std::mem::swap(o_prev, o_cur);
        }
        let mut logits = vec![0.0f32; vocab];
        tensor::gemm_nt_into(1, heads * dv, vocab, o_prev, &self.wo.data, &mut logits, false);
        logits
    }

    /// Replay a prompt's chunkwise span through a fresh [`LayerStack`]
    /// (identical code and gathered inputs as the serving path, fresh
    /// workspace — workspaces are inert) and export every (layer, head)
    /// into Mat-backed [`FenwickState`]s at the boundary.
    fn replay_prefill_span(&self, fed: &[i32], pe: usize) -> Vec<FenwickState> {
        let (layers, heads, dk, dv) = (self.layers, self.heads, self.dk, self.dv);
        if pe == 0 {
            return (0..layers * heads).map(|_| FenwickState::new(dk, dv)).collect();
        }
        let c = self.prefill_chunk;
        let mut ws = Workspace::new();
        let mut stack = LayerStack::new(layers, heads, dk, dv, c);
        let (mut qc, mut kc, mut vc) = (Vec::new(), Vec::new(), Vec::new());
        let mut pos = 0;
        while pos < pe {
            self.gather_chunk_inputs(&fed[pos..pos + c], &mut qc, &mut kc, &mut vc);
            stack.ingest_chunk(&mut ws, self.kind, &self.projs, &self.gates, pos, &qc, &kc, &vc, false);
            pos += c;
        }
        stack.finish();
        let mut states = Vec::with_capacity(layers * heads);
        for l in 0..layers {
            for h in 0..heads {
                states.push(FenwickState::import_levels(dk, dv, pe, &stack.export_head(l, h)));
            }
        }
        states
    }

    /// Per-sequence **oracle replay** of one request's full serving
    /// trajectory, on Mat-backed [`FenwickState`]s instead of the pool:
    /// the prompt's chunkwise span re-ingests through a fresh sequential
    /// [`LayerStack`] (identical code and inputs as the serving path, so
    /// identical floats), then every decode row steps token-by-token,
    /// layer-by-layer. Returns `(position, logits)` for every row the
    /// serving engine would feed through [`DecodeBackend::step`].
    ///
    /// `fed` is the exact token stream the server fed: the prompt followed
    /// by the sampled tokens except the last (which is never fed back).
    /// Bit-exactness with the pooled serving path — batched advance,
    /// batched read, batched projection and logits GEMMs, for any
    /// bucketing/scheduling — is the serving-trace differential property
    /// (`coordinator::trace`).
    pub fn oracle_decode_logits(&self, prompt_len: usize, fed: &[i32]) -> Vec<(usize, Vec<f32>)> {
        assert!(prompt_len >= 1 && prompt_len <= fed.len(), "fed must cover the prompt");
        let pe = self.prefill_boundary(prompt_len);
        let mut states = self.replay_prefill_span(fed, pe);
        let mut scratch = TokenScratch::default();
        let mut out = Vec::with_capacity(fed.len() - pe);
        for (p, &tok) in fed.iter().enumerate().skip(pe) {
            out.push((p, self.token_step_layers(&mut scratch, &mut states, tok, p)));
        }
        out
    }

    /// One-shot prompt-scoring oracle: the same chunk/tail split, stack
    /// code, logits GEMM shapes, and log-prob fold the serving
    /// `score_chunk`/`score_tail` path runs — in one call, independent of
    /// server scheduling and workspace state. `logprobs[i]` is
    /// `log P(tokens[i+1] | tokens[..=i])` (natural log); a 1-token
    /// prompt scores to an empty vector. The trace harness asserts served
    /// [`ScoreResult`](super::ScoreResult)s equal this bit-for-bit.
    pub fn oracle_score_logprobs(&self, tokens: &[i32]) -> Vec<f32> {
        let n = tokens.len();
        if n < 2 {
            return Vec::new();
        }
        let (layers, heads, dk, dv, vocab) =
            (self.layers, self.heads, self.dk, self.dv, self.vocab);
        let c = self.prefill_chunk;
        let pe = self.prefill_boundary(n);
        let mut lps = Vec::with_capacity(n - 1);
        let mut states: Vec<FenwickState>;
        if pe > 0 {
            let mut ws = Workspace::new();
            let mut stack = LayerStack::new(layers, heads, dk, dv, c);
            let (mut qc, mut kc, mut vc) = (Vec::new(), Vec::new(), Vec::new());
            let mut logits = vec![0.0f32; c * vocab];
            let mut pos = 0;
            while pos < pe {
                self.gather_chunk_inputs(&tokens[pos..pos + c], &mut qc, &mut kc, &mut vc);
                let o = stack
                    .ingest_chunk(&mut ws, self.kind, &self.projs, &self.gates, pos, &qc, &kc, &vc, true);
                tensor::gemm_nt_into(c, heads * dv, vocab, o, &self.wo.data, &mut logits, false);
                fold_score_logprobs(&logits, c, tokens, pos, &mut lps);
                pos += c;
            }
            stack.finish();
            states = Vec::with_capacity(layers * heads);
            for l in 0..layers {
                for h in 0..heads {
                    states.push(FenwickState::import_levels(dk, dv, pe, &stack.export_head(l, h)));
                }
            }
        } else {
            states = (0..layers * heads).map(|_| FenwickState::new(dk, dv)).collect();
        }
        // sub-chunk tail: positions pe .. n−2 step token-by-token (the
        // final token is never fed — nothing reads after it)
        let mut scratch = TokenScratch::default();
        for p in pe..n - 1 {
            let logits = self.token_step_layers(&mut scratch, &mut states, tokens[p], p);
            fold_score_logprobs(&logits, 1, tokens, p, &mut lps);
        }
        lps
    }

    /// The per-layer-barrier decode step body (pipelining off): per
    /// layer, build the whole bucket's inputs exactly as the unsharded
    /// path did, then run each shard's advance+read as one job —
    /// concurrently on the resident pool when sharded, inline on the
    /// caller thread with one shard (which keeps the nested row-parallel
    /// read fanning out across the pool's workers, the pre-sharding
    /// behavior). Leaves the final layer's `(n, H·d_v)` outputs in
    /// `self.o_buf` in bucket order; returns the first failure message.
    fn step_layerwise(
        &mut self,
        rows: &[(SeqSlot, i32, i32)],
        taken: &mut [(usize, Vec<PooledFenwickState>)],
    ) -> Option<String> {
        let (layers, heads, dk, dv, vocab) =
            (self.layers, self.heads, self.dk, self.dv, self.vocab);
        let n = rows.len();
        let nshards = self.pool.n_shards();
        for l in 0..layers {
            // whole-bucket layer inputs — identical to the unsharded path
            if l == 0 {
                self.q_rows.clear();
                self.k_rows.clear();
                self.v_rows.clear();
                for &(_, tok, _) in rows {
                    let ti = tok_index(tok, vocab);
                    for h in 0..heads {
                        self.q_rows.extend_from_slice(self.eq[h].row(ti));
                        self.k_rows.extend_from_slice(self.ek[h].row(ti));
                        self.v_rows.extend_from_slice(self.ev[h].row(ti));
                    }
                }
            } else {
                let _proj = crate::obs::span(crate::obs::SpanCat::Project, l as u64);
                let p = &self.projs[l - 1];
                self.q_rows.clear();
                self.q_rows.resize(n * heads * dk, 0.0);
                tensor::gemm_nt_into(n, heads * dv, heads * dk, &self.o_buf, &p.wq.data, &mut self.q_rows, false);
                self.k_rows.clear();
                self.k_rows.resize(n * heads * dk, 0.0);
                tensor::gemm_nt_into(n, heads * dv, heads * dk, &self.o_buf, &p.wk.data, &mut self.k_rows, false);
                normalize_keys(&mut self.k_rows, dk);
                self.v_rows.clear();
                self.v_rows.resize(n * heads * dv, 0.0);
                tensor::gemm_nt_into(n, heads * dv, heads * dv, &self.o_buf, &p.wv.data, &mut self.v_rows, false);
            }
            for (i, &(_, _, pos)) in rows.iter().enumerate() {
                for h in 0..heads {
                    debug_assert_eq!(taken[i].1[l * heads + h].t as i32, pos, "layer {l} desync");
                }
            }
            // this layer's &mut state slices, partitioned by shard (one
            // pass over `taken`, so within each shard the order is
            // bucket order — index-aligned with engine.rows)
            let mut shard_refs: Vec<Vec<&mut PooledFenwickState>> =
                (0..nshards).map(|_| Vec::new()).collect();
            for (slot_idx, seqs) in taken.iter_mut() {
                shard_refs[self.shard_of[*slot_idx]]
                    .extend(seqs[l * heads..(l + 1) * heads].iter_mut());
            }
            let mut parts = self.pool.parts_mut();
            // feasibility + cache eviction mutate the pool AND cache, so
            // they run sequentially before the concurrent jobs. The pool
            // may be over-reserved by cache-held blocks (inserts retain
            // beyond admission reservations): evict LRU entries until the
            // whole shard's advance plans fit — probed BEFORE
            // advance_bucket, because a mid-bucket refusal would leave
            // admitted sequences already stepped and a retry would
            // double-advance them.
            for (s, (pool_s, cache_s)) in parts.iter_mut().enumerate() {
                if shard_refs[s].is_empty() {
                    continue;
                }
                loop {
                    if bucket_feasible(pool_s, &shard_refs[s]) {
                        break;
                    }
                    let evicted = match cache_s.as_deref_mut() {
                        Some(c) => c.evict_lru(pool_s),
                        None => false,
                    };
                    if !evicted {
                        break;
                    }
                }
            }
            let mut fails: Vec<Option<String>> = (0..nshards).map(|_| None).collect();
            {
                let q_rows: &[f32] = &self.q_rows;
                let k_rows: &[f32] = &self.k_rows;
                let v_rows: &[f32] = &self.v_rows;
                let gates_l = &self.gates[l];
                let kind = self.kind;
                if nshards == 1 {
                    let (pool0, _) = parts.pop().expect("one shard");
                    run_shard_layer(
                        0, l, heads, dk, dv, kind, gates_l, rows, q_rows, k_rows, v_rows,
                        pool0, &mut self.engines[0], &mut shard_refs[0], &mut fails[0], false,
                    );
                } else {
                    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                        Vec::with_capacity(nshards);
                    for ((s, ((part, engine), refs)), fail) in parts
                        .into_iter()
                        .zip(self.engines.iter_mut())
                        .zip(shard_refs.iter_mut())
                        .enumerate()
                        .zip(fails.iter_mut())
                    {
                        if refs.is_empty() {
                            continue;
                        }
                        let (pool_s, _) = part;
                        jobs.push(Box::new(move || {
                            run_shard_layer(
                                s, l, heads, dk, dv, kind, gates_l, rows, q_rows, k_rows,
                                v_rows, pool_s, engine, refs, fail, true,
                            )
                        }));
                    }
                    resident_pool().scope(jobs);
                }
            }
            if let Some(msg) = fails.into_iter().flatten().next() {
                return Some(msg);
            }
            // scatter each shard's read outputs back into bucket order —
            // the next layer's projection operand and the logits operand
            self.o_buf.clear();
            self.o_buf.resize(n * heads * dv, 0.0);
            for engine in &self.engines {
                for (j, &i) in engine.rows.iter().enumerate() {
                    self.o_buf[i * heads * dv..(i + 1) * heads * dv]
                        .copy_from_slice(&engine.o[j * heads * dv..(j + 1) * heads * dv]);
                }
            }
        }
        None
    }

    /// The pipelined decode step body: ONE job per shard runs the FULL
    /// sequential layer stack over its rows — gather, advance, read,
    /// project — carrying the layer-boundary output buffer (`engine.o`,
    /// the pipeline register) across each [`LayerProjection`] boundary
    /// without re-synchronizing with the other shards between layers.
    /// Bit-exact with the layerwise body: every per-row computation is
    /// independent of batchmates, each sequence's states live wholly in
    /// its shard, and each sequence's per-layer op order is unchanged
    /// (docs/SHARDING.md has the full argument).
    fn step_pipelined(
        &mut self,
        rows: &[(SeqSlot, i32, i32)],
        taken: &mut [(usize, Vec<PooledFenwickState>)],
    ) -> Option<String> {
        let (layers, heads, dk, dv, vocab) =
            (self.layers, self.heads, self.dk, self.dv, self.vocab);
        let n = rows.len();
        let nshards = self.pool.n_shards();
        // each shard's sequences' full state vectors (bucket order,
        // index-aligned with engine.rows) — jobs re-slice per layer
        let mut shard_seqs: Vec<Vec<&mut Vec<PooledFenwickState>>> =
            (0..nshards).map(|_| Vec::new()).collect();
        for (slot_idx, seqs) in taken.iter_mut() {
            shard_seqs[self.shard_of[*slot_idx]].push(seqs);
        }
        let mut fails: Vec<Option<String>> = (0..nshards).map(|_| None).collect();
        {
            let mut parts = self.pool.parts_mut();
            let eq: &[Mat] = &self.eq;
            let ek: &[Mat] = &self.ek;
            let ev: &[Mat] = &self.ev;
            let projs: &[LayerProjection] = &self.projs;
            let gates: &[GateTable] = &self.gates;
            let kind = self.kind;
            if nshards == 1 {
                let (pool0, cache0) = parts.pop().expect("one shard");
                run_shard_stack(
                    0, layers, heads, dk, dv, vocab, kind, eq, ek, ev, projs, gates, rows,
                    pool0, cache0, &mut self.engines[0], &mut shard_seqs[0], &mut fails[0],
                    false,
                );
            } else {
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nshards);
                for ((s, ((part, engine), seqs)), fail) in parts
                    .into_iter()
                    .zip(self.engines.iter_mut())
                    .zip(shard_seqs.iter_mut())
                    .enumerate()
                    .zip(fails.iter_mut())
                {
                    if seqs.is_empty() {
                        continue;
                    }
                    let (pool_s, cache_s) = part;
                    jobs.push(Box::new(move || {
                        run_shard_stack(
                            s, layers, heads, dk, dv, vocab, kind, eq, ek, ev, projs, gates,
                            rows, pool_s, cache_s, engine, seqs, fail, true,
                        )
                    }));
                }
                resident_pool().scope(jobs);
            }
        }
        if let Some(msg) = fails.into_iter().flatten().next() {
            return Some(msg);
        }
        // scatter the final layer's outputs back into bucket order for
        // the shared logits GEMM
        self.o_buf.clear();
        self.o_buf.resize(n * heads * dv, 0.0);
        for engine in &self.engines {
            for (j, &i) in engine.rows.iter().enumerate() {
                self.o_buf[i * heads * dv..(i + 1) * heads * dv]
                    .copy_from_slice(&engine.o[j * heads * dv..(j + 1) * heads * dv]);
            }
        }
        None
    }
}

/// Clamp a sampled/user token into embedding range — the one token-id
/// convention for embeddings AND log-prob targets (the server's scoring
/// loop uses it too, so served log-probs match the oracle's exactly).
#[inline]
pub(crate) fn tok_index(tok: i32, vocab: usize) -> usize {
    (tok.max(0) as usize).min(vocab - 1)
}

/// Fold a block of consecutive per-position logits rows into per-token
/// log-probs: `logits` holds `rows` rows covering positions
/// `pos .. pos + rows` of `tokens`; for every target position `p` in
/// `pos+1 ..= min(pos + rows, tokens.len() − 1)` this appends
/// `log P(tokens[p] | …) = −cross_entropy(row_{p−1−pos}, tokens[p])` to
/// `out`. THE one log-prob fold — the server's scoring loop, the
/// scoring oracle, and the prefill bench all call it, so the subtle
/// row/target arithmetic cannot drift between them. A `rows = 0` block
/// folds nothing.
pub fn fold_score_logprobs(
    logits: &[f32],
    rows: usize,
    tokens: &[i32],
    pos: usize,
    out: &mut Vec<f32>,
) {
    if rows == 0 {
        return;
    }
    let vocab = logits.len() / rows;
    debug_assert_eq!(logits.len(), rows * vocab, "ragged logits block");
    let hi = (pos + rows).min(tokens.len() - 1);
    for p in pos + 1..=hi {
        let row = &logits[(p - 1 - pos) * vocab..(p - pos) * vocab];
        out.push(-tensor::ops::cross_entropy(row, tok_index(tokens[p], vocab)));
    }
}

/// One shard's slice of a single layer's decode work (layerwise mode):
/// build the shard's advance jobs against the whole-bucket k/v rows,
/// advance its own pool, then read back into the shard engine's output
/// buffer. `traced` adds the shard-step span — only the multi-shard path
/// passes true, so the single-shard hot path keeps its exact
/// pre-sharding hook-site count (decode_latency pins it).
#[allow(clippy::too_many_arguments)]
fn run_shard_layer(
    shard: usize,
    layer: usize,
    heads: usize,
    dk: usize,
    dv: usize,
    kind: TransitionKind,
    gates_l: &GateTable,
    rows: &[(SeqSlot, i32, i32)],
    q_rows: &[f32],
    k_rows: &[f32],
    v_rows: &[f32],
    pool: &mut StatePool,
    engine: &mut ShardEngine,
    refs: &mut Vec<&mut PooledFenwickState>,
    fail: &mut Option<String>,
    traced: bool,
) {
    let ns = engine.rows.len();
    debug_assert_eq!(refs.len(), ns * heads, "shard refs desync");
    let _st = traced.then(|| {
        crate::obs::span(crate::obs::SpanCat::ShardStep, ((shard as u64) << 32) | ns as u64)
    });
    let mut jobs: Vec<AdvanceJob<'_>> = Vec::with_capacity(ns * heads);
    for &i in &engine.rows {
        let pos = rows[i].2 as usize;
        for h in 0..heads {
            let e = i * heads + h;
            let k = &k_rows[e * dk..(e + 1) * dk];
            let v = &v_rows[e * dv..(e + 1) * dv];
            let alpha = gates_l.alpha_h(h, pos);
            let (write_scale, transition) = match kind {
                TransitionKind::Mamba2 => (1.0, Transition::Decay(alpha)),
                TransitionKind::Gdn => {
                    let beta = gates_l.beta_h(h, pos);
                    (beta, Transition::GatedHouseholder { alpha, beta, k })
                }
            };
            jobs.push(AdvanceJob { k, v, write_scale, transition });
        }
    }
    let refused = engine.adv.advance_bucket(pool, refs, &jobs);
    if !refused.is_empty() {
        // unreachable under admission reservation; surface loudly
        *fail = Some(format!("state pool exhausted mid-step at layer {layer} (reservation bug?)"));
        return;
    }
    // the shard's q rows, contiguous (engine.rows is bucket order, so
    // this is a gather of whole (H·d_k) row groups — bits unchanged)
    engine.q.clear();
    for &i in &engine.rows {
        engine.q.extend_from_slice(&q_rows[i * heads * dk..(i + 1) * heads * dk]);
    }
    engine.o.clear();
    engine.o.resize(ns * heads * dv, 0.0);
    let seq_refs: Vec<&PooledFenwickState> = refs.iter().map(|r| &**r).collect();
    let mut lambdas: Vec<&[f32]> = Vec::with_capacity(ns * heads);
    for &i in &engine.rows {
        let pos = rows[i].2 as usize;
        for h in 0..heads {
            lambdas.push(gates_l.lambda_h(h, pos));
        }
    }
    engine.dec.read_batch(pool, &seq_refs, &engine.q, &lambdas, &mut engine.o);
}

/// One shard's full-stack decode job (pipelined mode): all L layers over
/// the shard's rows, with per-layer feasibility probing and LRU eviction
/// against the shard's OWN pool and cache, and the engine's `o` buffer
/// as the pipeline register carried across [`LayerProjection`]
/// boundaries. Per-shard projections are row-slices of the whole-bucket
/// GEMMs (bit-exact per row), so this reorganization cannot change any
/// sequence's logits. `traced` gates the shard-step span as in
/// [`run_shard_layer`]; the per-layer pipeline-stage spans always emit —
/// pipelined mode is opt-in, never the measured default hot path.
#[allow(clippy::too_many_arguments)]
fn run_shard_stack(
    shard: usize,
    layers: usize,
    heads: usize,
    dk: usize,
    dv: usize,
    vocab: usize,
    kind: TransitionKind,
    eq: &[Mat],
    ek: &[Mat],
    ev: &[Mat],
    projs: &[LayerProjection],
    gates: &[GateTable],
    rows: &[(SeqSlot, i32, i32)],
    pool: &mut StatePool,
    mut cache: Option<&mut PrefixCache>,
    engine: &mut ShardEngine,
    owned: &mut [&mut Vec<PooledFenwickState>],
    fail: &mut Option<String>,
    traced: bool,
) {
    let ns = engine.rows.len();
    debug_assert_eq!(owned.len(), ns, "shard sequence list desync");
    let _st = traced.then(|| {
        crate::obs::span(crate::obs::SpanCat::ShardStep, ((shard as u64) << 32) | ns as u64)
    });
    for l in 0..layers {
        let _stage = crate::obs::span(
            crate::obs::SpanCat::PipelineStage,
            ((shard as u64) << 32) | l as u64,
        );
        if l == 0 {
            engine.q.clear();
            engine.k.clear();
            engine.v.clear();
            for &i in &engine.rows {
                let ti = tok_index(rows[i].1, vocab);
                for h in 0..heads {
                    engine.q.extend_from_slice(eq[h].row(ti));
                    engine.k.extend_from_slice(ek[h].row(ti));
                    engine.v.extend_from_slice(ev[h].row(ti));
                }
            }
        } else {
            // the pipeline register: layer l−1's outputs (engine.o) feed
            // this layer's projections without ever leaving the shard job
            let p = &projs[l - 1];
            engine.q.clear();
            engine.q.resize(ns * heads * dk, 0.0);
            tensor::gemm_nt_into(ns, heads * dv, heads * dk, &engine.o, &p.wq.data, &mut engine.q, false);
            engine.k.clear();
            engine.k.resize(ns * heads * dk, 0.0);
            tensor::gemm_nt_into(ns, heads * dv, heads * dk, &engine.o, &p.wk.data, &mut engine.k, false);
            normalize_keys(&mut engine.k, dk);
            engine.v.clear();
            engine.v.resize(ns * heads * dv, 0.0);
            tensor::gemm_nt_into(ns, heads * dv, heads * dv, &engine.o, &p.wv.data, &mut engine.v, false);
        }
        #[cfg(debug_assertions)]
        for (j, &i) in engine.rows.iter().enumerate() {
            let pos = rows[i].2;
            for h in 0..heads {
                debug_assert_eq!(owned[j][l * heads + h].t as i32, pos, "layer {l} desync");
            }
        }
        let gates_l = &gates[l];
        let mut jobs: Vec<AdvanceJob<'_>> = Vec::with_capacity(ns * heads);
        for (j, &i) in engine.rows.iter().enumerate() {
            let pos = rows[i].2 as usize;
            for h in 0..heads {
                let e = j * heads + h;
                let k = &engine.k[e * dk..(e + 1) * dk];
                let v = &engine.v[e * dv..(e + 1) * dv];
                let alpha = gates_l.alpha_h(h, pos);
                let (write_scale, transition) = match kind {
                    TransitionKind::Mamba2 => (1.0, Transition::Decay(alpha)),
                    TransitionKind::Gdn => {
                        let beta = gates_l.beta_h(h, pos);
                        (beta, Transition::GatedHouseholder { alpha, beta, k })
                    }
                };
                jobs.push(AdvanceJob { k, v, write_scale, transition });
            }
        }
        let mut refs: Vec<&mut PooledFenwickState> = owned
            .iter_mut()
            .flat_map(|seqs| seqs[l * heads..(l + 1) * heads].iter_mut())
            .collect();
        // per-shard feasibility + eviction: this shard's cache is the
        // only holder of unreserved blocks in this shard's pool, and no
        // other job touches either — same probe-before-advance argument
        // as the layerwise body
        loop {
            if bucket_feasible(pool, &refs) {
                break;
            }
            let evicted = match cache.as_deref_mut() {
                Some(c) => c.evict_lru(pool),
                None => false,
            };
            if !evicted {
                break;
            }
        }
        let refused = engine.adv.advance_bucket(pool, &mut refs, &jobs);
        if !refused.is_empty() {
            // unreachable under admission reservation; surface loudly
            *fail = Some(format!("state pool exhausted mid-step at layer {l} (reservation bug?)"));
            return;
        }
        engine.o.clear();
        engine.o.resize(ns * heads * dv, 0.0);
        let seq_refs: Vec<&PooledFenwickState> = refs.iter().map(|r| &**r).collect();
        let mut lambdas: Vec<&[f32]> = Vec::with_capacity(ns * heads);
        for &i in &engine.rows {
            let pos = rows[i].2 as usize;
            for h in 0..heads {
                lambdas.push(gates_l.lambda_h(h, pos));
            }
        }
        engine.dec.read_batch(pool, &seq_refs, &engine.q, &lambdas, &mut engine.o);
    }
}

impl DecodeBackend for PooledBackend {
    fn vocab(&self) -> usize {
        // the struct field, not recursion: field and method namespaces
        // are separate in Rust
        self.vocab
    }

    fn admit(&mut self, max_steps: usize) -> Result<SeqSlot, AdmitError> {
        // the prompt-blind form: no prefix to match, nothing cached
        self.admit_prompt(max_steps, &[]).map(|(slot, _)| slot)
    }

    fn admit_prompt(
        &mut self,
        max_steps: usize,
        prompt: &[i32],
    ) -> Result<(SeqSlot, usize), AdmitError> {
        let need = self.layers * self.heads * blocks_for_steps(max_steps.max(1));
        // per-shard bounds: a sequence's blocks live wholly in one shard,
        // so both "can never fit" and "cannot fit right now" are judged
        // against shard capacity, not the aggregate
        if need > self.pool.shard_capacity() {
            return Err(AdmitError::TooLarge);
        }
        // pin BEFORE the cache probe: a refused admission must not touch
        // any cache's LRU state (the single-shard path behaved that way,
        // and eviction order is part of the reproducibility story)
        let Some(default_shard) = self.pool.pin(need) else {
            return Err(AdmitError::Exhausted);
        };
        // consult the prefix caches over the prompt's chunkwise span
        // [0, pe): the longest chunk-aligned cached prefix (across all
        // shards) seeds this sequence's state without recomputing it.
        // Adoption only retains shared blocks (no allocation — it cannot
        // fail), so the reservation accounting is untouched: the adopted
        // blocks are the cache's, not this reservation's, until CoW
        // clones them.
        let pe = self.prefill_boundary(prompt.len());
        let hit = if pe > 0 { self.pool.lookup_prefix(&prompt[..pe]) } else { None };
        // a hit is only adoptable by a sequence pinned to the shard that
        // owns it (block ids are shard-local); when that shard has no
        // reservation headroom, fall back to the default pin and prefill
        // cold — correctness never depends on a hit, only speed
        let (shard, hit) = match hit {
            Some((s, m, states)) if self.pool.can_reserve(s, need) => (s, Some((m, states))),
            _ => (default_shard, None),
        };
        let (state, cached) = match hit {
            // full-boundary hit: every chunk the server would prefill is
            // cached — skip the stack entirely and decode off adopted
            // (shared, CoW-protected) pool blocks
            Some((m, states)) if m == pe => {
                let seqs = states
                    .iter()
                    .map(|per| {
                        PooledFenwickState::adopt_levels(
                            self.pool.shard_mut(shard),
                            self.dk,
                            self.dv,
                            pe,
                            per,
                        )
                    })
                    .collect();
                (SeqState::Decoding(seqs), m)
            }
            // partial hit: seed a prefill stack at the cached boundary
            // (byte-faithful copies of the cached blocks, so resumed
            // chunkwise prefill is bit-exact with a cold run) and let the
            // server feed the remaining chunks
            Some((m, states)) => {
                let z = m / self.prefill_chunk;
                // boundary reads go through the widening accessor so a
                // bf16 pool seeds the (always-f32) stack correctly; on an
                // f32 pool the copy is bitwise, so resumed prefill stays
                // bit-exact with a cold run
                let elems = self.dk * self.dv;
                let owned: Vec<Vec<(usize, Vec<f32>)>> = states
                    .iter()
                    .map(|per| {
                        per.iter()
                            .map(|&(lvl, id)| {
                                let mut buf = vec![0.0f32; elems];
                                self.pool.shard(shard).read_block_into(id, &mut buf);
                                (lvl, buf)
                            })
                            .collect()
                    })
                    .collect();
                let views: Vec<Vec<(usize, &[f32])>> = owned
                    .iter()
                    .map(|per| per.iter().map(|(lvl, buf)| (*lvl, buf.as_slice())).collect())
                    .collect();
                let stack = LayerStack::from_boundary(
                    self.layers,
                    self.heads,
                    self.dk,
                    self.dv,
                    self.prefill_chunk,
                    z,
                    &views,
                );
                (SeqState::Prefilling { stack, tokens: prompt[..m].to_vec() }, m)
            }
            // cold: a fresh sequence starts in prefill mode when the
            // backend has a chunked-prefill path; with it disabled,
            // decode states from step 0
            None if self.prefill_chunk > 0 => (
                SeqState::Prefilling {
                    stack: LayerStack::new(
                        self.layers,
                        self.heads,
                        self.dk,
                        self.dv,
                        self.prefill_chunk,
                    ),
                    tokens: Vec::new(),
                },
                0,
            ),
            None => (
                SeqState::Decoding(
                    (0..self.layers * self.heads)
                        .map(|_| PooledFenwickState::new(self.dk, self.dv))
                        .collect(),
                ),
                0,
            ),
        };
        self.pool.reserve(shard, need);
        let idx = match self.free_slots.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.reserved.push(0);
                self.shard_of.push(0);
                self.slots.len() - 1
            }
        };
        self.slots[idx] = Some(state);
        self.reserved[idx] = need;
        self.shard_of[idx] = shard;
        Ok((SeqSlot(idx), cached))
    }

    fn retire(&mut self, slot: SeqSlot) {
        let shard = self.shard_of[slot.0];
        match self.slots[slot.0].take().expect("retire of free slot") {
            // stack / scoring states live outside the pool
            SeqState::Prefilling { .. } | SeqState::Scoring(_) => {}
            SeqState::Decoding(seqs) => {
                let pool = self.pool.shard_mut(shard);
                for mut seq in seqs {
                    seq.release(pool);
                }
            }
        }
        self.pool.unreserve(shard, self.reserved[slot.0]);
        self.reserved[slot.0] = 0;
        self.free_slots.push(slot.0);
        self.debug_assert_no_block_leaks();
    }

    fn pool_occupancy(&self) -> (usize, usize) {
        (self.pool.in_use(), self.pool.peak())
    }

    fn prefill_chunk_size(&self) -> usize {
        self.prefill_chunk
    }

    fn prefill_chunk(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<()> {
        let c = self.prefill_chunk;
        if c == 0 {
            bail!("chunked prefill disabled on this backend");
        }
        if tokens.len() != c {
            bail!("prefill chunk must be exactly {c} tokens, got {}", tokens.len());
        }
        {
            let state = self.slots[slot.0].as_ref().expect("prefill of free slot");
            let SeqState::Prefilling { stack, .. } = state else {
                bail!("prefill_chunk after decode began");
            };
            if stack.tokens() != pos {
                bail!("prefill position desync: stack at {}, chunk at {pos}", stack.tokens());
            }
        }
        // layer-0 inputs via the one shared gather, into persistent
        // buffers taken out for the call (serving hot path — no
        // steady-state allocation); layers ≥ 1 derive inside the stack
        let mut qc = std::mem::take(&mut self.qc_buf);
        let mut kc = std::mem::take(&mut self.kc_buf);
        let mut vc = std::mem::take(&mut self.vc_buf);
        self.gather_chunk_inputs(tokens, &mut qc, &mut kc, &mut vc);
        let Some(SeqState::Prefilling { stack, tokens: record }) = self.slots[slot.0].as_mut()
        else {
            unreachable!("checked above")
        };
        stack.ingest_chunk(&mut self.ws, self.kind, &self.projs, &self.gates, pos, &qc, &kc, &vc, false);
        record.extend_from_slice(tokens);
        debug_assert_eq!(record.len(), stack.tokens(), "prefix record desync");
        self.qc_buf = qc;
        self.kc_buf = kc;
        self.vc_buf = vc;
        Ok(())
    }

    fn supports_scoring(&self) -> bool {
        true
    }

    fn score_admit(&mut self) -> Result<SeqSlot, AdmitError> {
        // scoring never touches the pool (stack + Mat-backed tail), so
        // admission is just a slot: scoring cannot starve decode of state
        // blocks, and decode backpressure never rejects scoring
        let idx = match self.free_slots.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.reserved.push(0);
                self.shard_of.push(0);
                self.slots.len() - 1
            }
        };
        let stack = (self.prefill_chunk > 0).then(|| {
            LayerStack::new(self.layers, self.heads, self.dk, self.dv, self.prefill_chunk)
        });
        self.slots[idx] = Some(SeqState::Scoring(ScoreSeq { stack, tail: Vec::new() }));
        self.reserved[idx] = 0;
        self.shard_of[idx] = 0;
        Ok(SeqSlot(idx))
    }

    fn score_chunk(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<Vec<f32>> {
        let c = self.prefill_chunk;
        if c == 0 {
            bail!("chunked scoring needs a prefill chunk size");
        }
        if tokens.len() != c {
            bail!("score chunk must be exactly {c} tokens, got {}", tokens.len());
        }
        {
            let Some(SeqState::Scoring(sc)) = self.slots[slot.0].as_ref() else {
                bail!("score_chunk on a non-scoring slot");
            };
            let Some(stack) = sc.stack.as_ref() else {
                bail!("score_chunk after the tail began");
            };
            if stack.tokens() != pos {
                bail!("scoring position desync: stack at {}, chunk at {pos}", stack.tokens());
            }
        }
        let mut qc = std::mem::take(&mut self.qc_buf);
        let mut kc = std::mem::take(&mut self.kc_buf);
        let mut vc = std::mem::take(&mut self.vc_buf);
        self.gather_chunk_inputs(tokens, &mut qc, &mut kc, &mut vc);
        let Some(SeqState::Scoring(sc)) = self.slots[slot.0].as_mut() else {
            unreachable!("checked above")
        };
        let stack = sc.stack.as_mut().expect("checked above");
        let o =
            stack.ingest_chunk(&mut self.ws, self.kind, &self.projs, &self.gates, pos, &qc, &kc, &vc, true);
        // the chunk's per-token logits from the last layer's outputs —
        // the same GEMM shape the scoring oracle replays
        let mut logits = vec![0.0f32; c * self.vocab];
        tensor::gemm_nt_into(c, self.heads * self.dv, self.vocab, o, &self.wo.data, &mut logits, false);
        self.qc_buf = qc;
        self.kc_buf = kc;
        self.vc_buf = vc;
        Ok(logits)
    }

    fn score_tail(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<Vec<f32>> {
        {
            let Some(SeqState::Scoring(_)) = self.slots[slot.0].as_ref() else {
                bail!("score_tail on a non-scoring slot");
            };
        }
        let Some(SeqState::Scoring(mut sc)) = self.slots[slot.0].take() else {
            unreachable!("checked above")
        };
        if sc.tail.is_empty() {
            // flip the stack into Mat-backed token states at the boundary
            if let Some(mut stack) = sc.stack.take() {
                if stack.tokens() != pos {
                    let at = stack.tokens();
                    // put the stack back before bailing: a dropped stack
                    // would make a later correct call silently score with
                    // no prompt prefix (or bail with a misleading error)
                    sc.stack = Some(stack);
                    self.slots[slot.0] = Some(SeqState::Scoring(sc));
                    bail!("scoring tail desync: stack at {at}, tail at {pos}");
                }
                stack.finish();
                for l in 0..self.layers {
                    for h in 0..self.heads {
                        sc.tail.push(FenwickState::import_levels(
                            self.dk,
                            self.dv,
                            pos,
                            &stack.export_head(l, h),
                        ));
                    }
                }
            } else {
                if pos != 0 {
                    self.slots[slot.0] = Some(SeqState::Scoring(sc));
                    bail!("scoring tail at position {pos} without a chunk span");
                }
                sc.tail = (0..self.layers * self.heads)
                    .map(|_| FenwickState::new(self.dk, self.dv))
                    .collect();
            }
        }
        let mut logits = Vec::with_capacity(tokens.len() * self.vocab);
        let mut scratch = TokenScratch::default();
        for (j, &tok) in tokens.iter().enumerate() {
            let row = self.token_step_layers(&mut scratch, &mut sc.tail, tok, pos + j);
            logits.extend_from_slice(&row);
        }
        self.slots[slot.0] = Some(SeqState::Scoring(sc));
        Ok(logits)
    }

    fn step(&mut self, _bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (heads, dv, vocab) = (self.heads, self.dv, self.vocab);
        // 0) rows arriving from chunked prefill flip to pooled decode
        //    states via the export bridge
        for &(slot, _, _) in rows {
            self.ensure_decoding(slot)?;
        }
        // take every row's states out of its slot for the duration so
        // each per-layer pass can hold one &mut per entry without unsafe
        let mut taken: Vec<(usize, Vec<PooledFenwickState>)> = Vec::with_capacity(n);
        for &(slot, _, _) in rows {
            let Some(SeqState::Decoding(seqs)) = self.slots[slot.0].take() else {
                unreachable!("ensured above")
            };
            taken.push((slot.0, seqs));
        }
        // partition the bucket by pinned shard (bucket order within each
        // shard, so per-shard outputs scatter back positionally)
        for e in self.engines.iter_mut() {
            e.rows.clear();
        }
        for (i, (slot_idx, _)) in taken.iter().enumerate() {
            self.engines[self.shard_of[*slot_idx]].rows.push(i);
        }
        // 1..L) the sequential layer stack, in one of two shapes: the
        //    per-layer barrier (every shard synchronizes between layers —
        //    with one shard this IS the pre-sharding path, bit-for-bit
        //    and span-for-span) or the per-shard full-stack pipeline.
        //    Both leave the last layer's (n, H·dv) outputs in o_buf.
        let failed = if self.pipelined {
            self.step_pipelined(rows, &mut taken)
        } else {
            self.step_layerwise(rows, &mut taken)
        };
        for (slot_idx, seqs) in taken {
            self.slots[slot_idx] = Some(SeqState::Decoding(seqs));
        }
        if let Some(msg) = failed {
            bail!(msg);
        }
        // per-shard occupancy instants, only when actually sharded — the
        // single-shard hot path keeps its exact pre-sharding hook count
        if self.pool.n_shards() > 1 {
            for s in 0..self.pool.n_shards() {
                crate::obs::instant(
                    crate::obs::SpanCat::ShardOccupancy,
                    ((s as u64) << 32) | self.pool.shard(s).in_use() as u64,
                );
            }
        }
        // final) whole-batch logits in one GEMM: (n, H·dv) @ (vocab, H·dv)^T
        let _lg = crate::obs::span(crate::obs::SpanCat::Logits, n as u64);
        let mut logits = vec![0.0f32; n * vocab];
        tensor::gemm_nt_into(n, heads * dv, vocab, &self.o_buf, &self.wo.data, &mut logits, false);
        Ok(logits)
    }

    fn state_bytes(&self) -> usize {
        let off_pool: usize = self
            .slots
            .iter()
            .flatten()
            .map(|s| match s {
                SeqState::Prefilling { stack, .. } => stack.state_bytes(),
                SeqState::Scoring(sc) => {
                    sc.stack.as_ref().map(|st| st.state_bytes()).unwrap_or(0)
                        + sc.tail.iter().map(|f| f.state_bytes()).sum::<usize>()
                }
                SeqState::Decoding(_) => 0,
            })
            .sum();
        // pool bytes follow the storage precision: 4 bytes/elem at f32,
        // 2 at bf16 — the `state_bytes_per_seq` headline's denominator
        self.pool.in_use() * self.pool.block_elems() * self.pool.precision().bytes_per_elem()
            + off_pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefill::stack::test_support::naive_sequential_outputs;

    /// Naive per-token, per-layer recurrent reference for the backend's
    /// sequential LM over a fed token stream: layer-0 inputs gathered
    /// from the token embeddings, then the ONE shared naive stack
    /// reference (`prefill::stack::test_support`) — completely bypassing
    /// the chunkwise engines, the stack, the pool, and the batched
    /// passes — and the output head. Returns per-position logits
    /// `(T, vocab)`.
    fn naive_lm_logits(b: &PooledBackend, fed: &[i32]) -> Mat {
        let t = fed.len();
        let gather = |e: &[Mat], d: usize| -> Vec<Mat> {
            (0..b.heads)
                .map(|h| Mat::from_fn(t, d, |i, j| e[h].at(tok_index(fed[i], b.vocab), j)))
                .collect()
        };
        let (qs0, ks0, vs0) = (gather(&b.eq, b.dk), gather(&b.ek, b.dk), gather(&b.ev, b.dv));
        let o = naive_sequential_outputs(b.kind, &qs0, &ks0, &vs0, &b.projs, &b.gates);
        let mut logits = Mat::zeros(t, b.vocab);
        tensor::gemm_nt_into(t, b.heads * b.dv, b.vocab, &o.data, &b.wo.data, &mut logits.data, false);
        logits
    }

    /// THE sequential-model equivalence (satellite): L = 2, 3 chunkwise
    /// prefill + decode — via the oracle replay the trace harness proves
    /// bit-exact with the serving path — against the naive per-token,
    /// per-layer recurrent reference, for both transition families,
    /// including a sub-chunk prompt tail and a decode span. Prompt
    /// scoring is checked against the same reference.
    #[test]
    fn sequential_serve_and_scoring_match_naive_recurrent_reference() {
        let mut rng = Rng::new(0xBAC0);
        for &layers in &[2usize, 3] {
            for kind in [TransitionKind::Mamba2, TransitionKind::Gdn] {
                let b = PooledBackend::with_model_config(
                    32,
                    layers,
                    2,
                    kind,
                    6,
                    6,
                    4,
                    4096,
                    0xFEED + layers as u64,
                );
                // 11-token prompt = 2 full chunks + a 3-token sub-chunk
                // tail, then a 4-row decode span
                let prompt_len = 11usize;
                let fed: Vec<i32> = (0..prompt_len + 4).map(|_| rng.below(32) as i32).collect();
                let naive = naive_lm_logits(&b, &fed);
                let oracle = b.oracle_decode_logits(prompt_len, &fed);
                assert_eq!(oracle[0].0, b.prefill_boundary(prompt_len));
                assert_eq!(oracle.len(), fed.len() - b.prefill_boundary(prompt_len));
                for (p, logits) in &oracle {
                    for j in 0..b.vocab {
                        let (g, w) = (logits[j], naive.at(*p, j));
                        assert!(
                            (g - w).abs() < 5e-3 + 1e-2 * w.abs(),
                            "L={layers} {kind:?} pos={p} vocab={j}: {g} vs {w}"
                        );
                    }
                }
                // prompt scoring against the same reference:
                // logprobs[p-1] folds the naive row at p-1
                let lps = b.oracle_score_logprobs(&fed[..prompt_len]);
                assert_eq!(lps.len(), prompt_len - 1);
                for p in 1..prompt_len {
                    let want =
                        -tensor::ops::cross_entropy(naive.row(p - 1), tok_index(fed[p], b.vocab));
                    assert!(
                        (lps[p - 1] - want).abs() < 2e-2 + 2e-2 * want.abs(),
                        "L={layers} {kind:?} score target {p}: {} vs {want}",
                        lps[p - 1]
                    );
                }
            }
        }
    }

    /// Serve one request end-to-end at the backend interface: admit with
    /// the prompt visible, feed the uncached prefill chunks, then step
    /// every remaining fed token one row at a time. Returns the logits
    /// rows for positions `prefill_boundary(plen) .. fed.len()`.
    fn serve(
        b: &mut PooledBackend,
        plen: usize,
        fed: &[i32],
        expect_cached: usize,
    ) -> Vec<Vec<f32>> {
        let (slot, cached) = b.admit_prompt(64, &fed[..plen]).unwrap();
        assert_eq!(cached, expect_cached, "cached prompt tokens");
        let c = b.prefill_chunk_size();
        let pe = b.prefill_boundary(plen);
        let mut pos = cached;
        while pos + c <= pe {
            b.prefill_chunk(slot, &fed[pos..pos + c], pos).unwrap();
            pos += c;
        }
        let mut out = Vec::new();
        for p in pe..fed.len() {
            out.push(b.step(1, &[(slot, fed[p], p as i32)]).unwrap());
        }
        b.retire(slot);
        out
    }

    fn assert_rows_bit_eq(got: &[Vec<f32>], want: &[(usize, Vec<f32>)], tag: &str) {
        assert_eq!(got.len(), want.len(), "{tag}: row count");
        for (row, (p, w)) in got.iter().zip(want) {
            assert_eq!(row.len(), w.len(), "{tag}: pos {p} width");
            for (j, (a, b)) in row.iter().zip(w).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: pos {p} logit {j}: {a} vs {b}");
            }
        }
    }

    /// Tentpole lock at the backend interface: admissions served off the
    /// prefix cache — a partial hit (resume chunkwise prefill from the
    /// cached boundary) and a full-boundary hit (decode directly off
    /// adopted CoW blocks) — produce logits **bit-identical** to the
    /// cold oracle replay, for both transition families. Also pins the
    /// cache-key growth: a resumed prefill publishes its *extended*
    /// boundary, upgrading the next identical prompt to a full hit.
    #[test]
    fn prefix_cache_partial_and_full_hits_are_bit_exact_with_cold_serving() {
        for kind in [TransitionKind::Mamba2, TransitionKind::Gdn] {
            let mut b =
                PooledBackend::with_model_config(32, 2, 2, kind, 6, 6, 4, 4096, 0xCA4E);
            b.enable_prefix_cache();
            let mut rng = Rng::new(0x5EED);
            // 16-token fed stream; the long prompt is its first 13 tokens
            // (boundary 12 = 3 chunks), the short one its first 9
            // (boundary 8 = 2 chunks)
            let fed: Vec<i32> = (0..16).map(|_| rng.below(32) as i32).collect();
            let oracle_short = b.oracle_decode_logits(9, &fed);
            let oracle_long = b.oracle_decode_logits(13, &fed);

            // cold: populates the 8-token key
            let cold = serve(&mut b, 9, &fed, 0);
            assert_rows_bit_eq(&cold, &oracle_short, "cold");
            let cache = b.prefix_cache().unwrap();
            assert_eq!(cache.len(), 1);
            // retiring the exporter left only the cache's refcounts live
            assert_eq!(b.pool().in_use(), b.prefix_cache().unwrap().blocks_held());

            // partial hit: 8 of 12 boundary tokens cached; prefill
            // resumes at chunk 2 and publishes the 12-token boundary
            let partial = serve(&mut b, 13, &fed, 8);
            assert_rows_bit_eq(&partial, &oracle_long, "partial hit");
            assert_eq!(b.prefix_cache().unwrap().len(), 2);

            // full-boundary hit: no prefill at all, decode off adopted
            // shared blocks (copy-on-write protects the cached bytes)
            let full = serve(&mut b, 13, &fed, 12);
            assert_rows_bit_eq(&full, &oracle_long, "full hit");

            // and the cached bytes really were protected: a fourth
            // admission still full-hits and still matches
            let again = serve(&mut b, 13, &fed, 12);
            assert_rows_bit_eq(&again, &oracle_long, "repeat full hit");
        }
    }

    /// bf16 serving lock at the backend interface: the same request
    /// served off an f32 pool and a bf16 pool ([`PooledBackend::set_precision`])
    /// produces logits within the documented relative-error bound of each
    /// other (docs/PRECISION.md), pool bytes per block halve, and
    /// retirement drains both pools to zero. Also pins that prefix-cache
    /// hits keep working across the precision boundary: cached bf16
    /// boundary blocks widen on adoption.
    #[test]
    fn bf16_precision_serves_within_tolerance_and_halves_pool_bytes() {
        for kind in [TransitionKind::Mamba2, TransitionKind::Gdn] {
            let mut rng = Rng::new(0xBF16);
            let fed: Vec<i32> = (0..16).map(|_| rng.below(32) as i32).collect();
            let mut b32 =
                PooledBackend::with_model_config(32, 2, 2, kind, 6, 6, 4, 4096, 0xCAFE);
            let mut b16 =
                PooledBackend::with_model_config(32, 2, 2, kind, 6, 6, 4, 4096, 0xCAFE);
            b16.set_precision(Precision::Bf16);
            assert_eq!(b16.precision(), Precision::Bf16);
            assert_eq!(b32.precision(), Precision::F32);
            assert_eq!(
                b16.pool().shard(0).bytes_per_block() * 2,
                b32.pool().shard(0).bytes_per_block(),
                "bf16 halves pool bytes per block"
            );
            let want = serve(&mut b32, 13, &fed, 0);
            let got = serve(&mut b16, 13, &fed, 0);
            assert_eq!(got.len(), want.len());
            for (row_g, row_w) in got.iter().zip(&want) {
                for (g, w) in row_g.iter().zip(row_w) {
                    let rel = (g - w).abs() / (1.0 + w.abs());
                    assert!(rel <= 0.05, "{kind:?}: bf16 logit {g} vs f32 {w} (rel {rel})");
                }
            }
            assert_eq!(b16.pool().in_use(), 0, "bf16 pool drained after retire");

            // prefix-cache round trip at bf16: publish, then full-hit
            b16.enable_prefix_cache();
            let cold = serve(&mut b16, 13, &fed, 0);
            let hit = serve(&mut b16, 13, &fed, 12);
            assert_rows_bit_eq(
                &hit,
                &cold.iter().enumerate().map(|(i, r)| (i, r.clone())).collect::<Vec<_>>(),
                "bf16 full cache hit replays the published boundary bitwise",
            );
        }
    }

    #[test]
    #[should_panic(expected = "set_precision with live sequences resident")]
    fn set_precision_refuses_resident_sequences() {
        let mut b = PooledBackend::with_config(32, 1, 4, 4, 0, 64, 7);
        let _slot = b.admit(4).unwrap();
        b.set_precision(Precision::Bf16);
    }

    /// A single-layer sequential model must reproduce the pre-sequential
    /// single-layer backend exactly: same RNG draw order, same weights,
    /// and (because one layer has no projections) the same decode math.
    /// Guarded here by checking layer-0 embeddings and the output head
    /// shape stay as documented.
    #[test]
    fn single_layer_config_shapes_and_draws_are_preserved() {
        let b = PooledBackend::with_config(64, 3, 8, 6, 4, 128, 9);
        assert_eq!(b.layers, 1);
        assert_eq!(b.eq.len(), 3);
        assert!(b.projs.is_empty());
        assert_eq!((b.wo.rows, b.wo.cols), (64, 3 * 6));
        // keys L2-normalized per embedding row
        for h in 0..3 {
            for i in 0..64 {
                let n = crate::tensor::ops::l2_norm(b.ek[h].row(i));
                assert!((n - 1.0).abs() < 1e-4, "head {h} row {i}: key norm {n}");
            }
        }
    }
}
