//! Decode execution backends behind the serving engine.
//!
//! [`DecodeServer`](super::server::DecodeServer) owns queueing, batching,
//! sampling, and retirement; *how* a batch of (token, position) rows is
//! stepped — and how per-sequence state is held — is a [`DecodeBackend`]:
//!
//! - [`PjrtBackend`]: the AOT path. Per-sequence dense state stacks are
//!   gathered into batched PJRT buffers, the compiled `decode_step`
//!   executes, states scatter back. Admission never backpressures (dense
//!   stacks are host `Vec`s) and prompts are ingested token-by-token.
//! - [`PooledBackend`]: the pure-Rust pooled engine. An L-layer H-head
//!   log-linear attention LM (Mamba-2 or GDN transitions, see
//!   [`TransitionKind`]) whose per-(sequence, layer, head) Fenwick states
//!   live in a shared [`StatePool`]; each decode step is matmul-rich —
//!   one pool-wide [`BatchedAdvance::advance_bucket`] pass (every entry's
//!   merge + transition + sentinel write as batched slab dispatches), one
//!   [`BatchedDecoder::read_batch`] block-sparse GEMM over every live
//!   level of every entry, then one `O_cat @ W_o^T` GEMM for the whole
//!   batch's logits. Prompts are ingested **chunkwise**:
//!   [`DecodeBackend::prefill_chunk`] streams full chunks through
//!   per-sequence per-layer head-batched
//!   [`PrefillEngine`](crate::prefill::PrefillEngine)s (state-only Alg. 1
//!   — no logits until the prompt's final token), and the first decode
//!   row flips the sequence to pooled decode states via the export bridge
//!   ([`crate::prefill::bridge::export_prefill_head`]). Position- (and
//!   optionally head-)dependent gates come from one [`GateTable`] per
//!   layer consulted by both paths, so chunkwise-prefilled and
//!   token-stepped sequences follow the same α/β/λ schedules.
//!   [`DecodeBackend::admit`] reserves
//!   `layers · heads · blocks_for_steps(max_steps)` pool blocks per
//!   sequence and returns [`AdmitError::Exhausted`] when the pool can't
//!   hold another sequence — the backpressure signal the server's
//!   admission loop honors by leaving requests queued.

use anyhow::{bail, Result};

use crate::prefill::bridge::export_prefill_head;
use crate::prefill::PrefillEngine;
use crate::runtime::{ModelHandle, Runtime};
use crate::state::pool::StatePool;
use crate::state::pooled::{blocks_for_steps, BatchedDecoder, PooledFenwickState};
use crate::state::{AdvanceJob, BatchedAdvance, FenwickState, GateTable, Transition};
use crate::tensor::{self, Mat};
use crate::util::Rng;

/// Backend-side handle for one admitted sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqSlot(pub usize);

/// Why admission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// No resources *right now* — retry once running sequences retire
    /// (the batcher keeps the request queued).
    Exhausted,
    /// The request can never fit this backend (e.g. needs more state
    /// blocks than the whole pool holds) — reject it.
    TooLarge,
}

/// One decode execution engine (state storage + step function).
pub trait DecodeBackend {
    /// Reserve resources for a sequence running at most `max_steps`
    /// decode steps; returns the slot to pass to [`DecodeBackend::step`].
    fn admit(&mut self, max_steps: usize) -> Result<SeqSlot, AdmitError>;

    /// Release a sequence's resources.
    fn retire(&mut self, slot: SeqSlot);

    /// Execute one decode step for `rows` of (slot, token, position) in a
    /// `bucket`-sized batch (`rows.len() <= bucket`; padding, if the
    /// backend needs fixed shapes, is backend-internal). Returns logits
    /// `(rows.len(), vocab)` row-major.
    fn step(&mut self, bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>>;

    /// Resident decode-state bytes right now (peak accounting).
    fn state_bytes(&self) -> usize;

    /// Chunk size for chunked prompt prefill; 0 = unsupported (the server
    /// then feeds prompts token-by-token through [`DecodeBackend::step`],
    /// the pre-prefill behavior).
    fn prefill_chunk_size(&self) -> usize {
        0
    }

    /// Ingest one full prompt chunk for `slot`: `tokens` are the prompt
    /// tokens at positions `pos .. pos + tokens.len()`, state-only (no
    /// logits — the prompt's final token goes through
    /// [`DecodeBackend::step`] to produce the first sample). Only valid
    /// before the sequence's first decode row, with
    /// `tokens.len() == prefill_chunk_size()` and chunk-aligned `pos`.
    fn prefill_chunk(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<()> {
        let _ = (slot, tokens, pos);
        bail!("this backend does not support chunked prefill")
    }
}

// ---------------------------------------------------------------------------
// PJRT (AOT artifact) backend
// ---------------------------------------------------------------------------

/// The compiled-artifact backend: dense per-layer state stacks per
/// sequence, batched through the AOT `decode_step` executables.
pub struct PjrtBackend {
    model: ModelHandle,
    state_numels: Vec<usize>,
    dense_state_bytes_per_seq: usize,
    /// per-slot per-layer flat states (None = free slot)
    slots: Vec<Option<Vec<Vec<f32>>>>,
    free_slots: Vec<usize>,
}

impl PjrtBackend {
    /// Compile the decode executables for every bucket up front.
    pub fn new(rt: &Runtime, mut model: ModelHandle, buckets: &[usize]) -> Result<PjrtBackend> {
        for &b in buckets {
            model.ensure_decode(rt, b)?;
        }
        let state_numels: Vec<usize> = model
            .manifest
            .state_shapes
            .iter()
            .map(|s| s.iter().product())
            .collect();
        let dense = state_numels.iter().sum::<usize>() * 4;
        Ok(PjrtBackend {
            model,
            state_numels,
            dense_state_bytes_per_seq: dense,
            slots: Vec::new(),
            free_slots: Vec::new(),
        })
    }

    pub fn model(&self) -> &ModelHandle {
        &self.model
    }
}

impl DecodeBackend for PjrtBackend {
    fn admit(&mut self, _max_steps: usize) -> Result<SeqSlot, AdmitError> {
        let states: Vec<Vec<f32>> = self.state_numels.iter().map(|&n| vec![0.0f32; n]).collect();
        let idx = match self.free_slots.pop() {
            Some(i) => {
                self.slots[i] = Some(states);
                i
            }
            None => {
                self.slots.push(Some(states));
                self.slots.len() - 1
            }
        };
        Ok(SeqSlot(idx))
    }

    fn retire(&mut self, slot: SeqSlot) {
        assert!(self.slots[slot.0].take().is_some(), "retire of free slot");
        self.free_slots.push(slot.0);
    }

    fn step(&mut self, bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 || n > bucket {
            bail!("bad batch: {n} rows for bucket {bucket}");
        }
        let layers = self.state_numels.len();
        // gather into the fixed (bucket, ...) shapes the artifact expects
        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        let mut batched: Vec<Vec<f32>> = self
            .state_numels
            .iter()
            .map(|&numel| vec![0.0f32; bucket * numel])
            .collect();
        for (i, &(slot, tok, p)) in rows.iter().enumerate() {
            tokens[i] = tok;
            pos[i] = p;
            let st = self.slots[slot.0].as_ref().expect("live slot");
            for (l, layer) in st.iter().enumerate() {
                let numel = self.state_numels[l];
                batched[l][i * numel..(i + 1) * numel].copy_from_slice(layer);
            }
        }
        let mut logits = self.model.decode_step(bucket, &mut batched, &tokens, &pos)?;
        // scatter back
        for (i, &(slot, _, _)) in rows.iter().enumerate() {
            let st = self.slots[slot.0].as_mut().expect("live slot");
            for l in 0..layers {
                let numel = self.state_numels[l];
                st[l].copy_from_slice(&batched[l][i * numel..(i + 1) * numel]);
            }
        }
        // drop padding rows in place — no copy in the full-bucket case
        let vocab = logits.len() / bucket;
        logits.truncate(n * vocab);
        Ok(logits)
    }

    fn state_bytes(&self) -> usize {
        self.slots.iter().flatten().count() * self.dense_state_bytes_per_seq
    }
}

// ---------------------------------------------------------------------------
// Pooled pure-Rust backend
// ---------------------------------------------------------------------------

/// Which per-token state transition the backend's attention states apply
/// (both serving paths: chunkwise prefill and pooled decode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransitionKind {
    /// Mamba-2 scalar decay: `S ← α S`, sentinel write scale 1.
    Mamba2,
    /// Gated DeltaNet: `S ← α (I − β k k^T) S`, sentinel write scale β
    /// (keys are L2-normalized so the Householder stays contractive).
    Gdn,
}

/// One admitted sequence's backend-side state: per-layer head-batched
/// chunkwise prefill engines while the prompt streams in, then per-(layer,
/// head) pool-backed decode states (flipped by the export bridge on the
/// first decode row). Both vectors are layer-major (decode states are
/// additionally head-minor: index `l · heads + h`).
enum SeqState {
    Prefilling(Vec<PrefillEngine>),
    Decoding(Vec<PooledFenwickState>),
}

/// Pure-Rust pooled decode backend: a fixed-weight L-layer H-head
/// log-linear attention LM (random per-(layer, head) embeddings + one
/// output head over the concatenated layer outputs) whose decode states
/// live in a shared [`StatePool`] and whose prompts ingest chunkwise
/// through per-sequence, per-layer [`PrefillEngine`]s. Exists to serve
/// real token traffic through the batched Fenwick engines without PJRT —
/// the scheduler/backpressure testbed and the bench engine for
/// `decode_batched` / `prefill_throughput`.
///
/// **Model layout (multi-layer).** Layer `l` is an independent H-head
/// log-linear attention branch over the token stream: per-(layer, head)
/// q/k/v embeddings, a per-layer [`GateTable`] (α/β/λ schedules, optionally
/// per-head), and per-(sequence, layer, head) Fenwick level states in the
/// one shared pool. A step's hidden output is the `(n, L·H·d_v)`
/// concatenation of every layer's head outputs; logits are one
/// `O_cat @ W_o^T` GEMM against the `(vocab, L·H·d_v)` output head.
/// Layers are parallel branches rather than a sequential hidden-state
/// stack: feeding layer `l`'s per-token outputs into layer `l+1` during
/// *chunkwise prefill* would need intra-chunk attention outputs, which the
/// state-only prefill engine deliberately skips (see the prompt-scoring
/// open item in ROADMAP.md); parallel branches keep chunkwise-prefilled
/// and token-stepped trajectories bit-identical, which the serving-trace
/// differential harness depends on.
///
/// **Step structure.** Every decode step runs exactly two batched passes
/// over all `n · L · H` (sequence, layer, head) entries of the bucket:
/// one pool-wide [`BatchedAdvance::advance_bucket`] (merge + transition +
/// sentinel write as slab dispatches — the per-sequence `advance` loop it
/// replaces is benched against it in `decode_batched`), then one
/// [`BatchedDecoder::read_batch`] block-sparse GEMM, whose entry order
/// (sequence-major, layer, head) makes the output buffer the logits
/// GEMM's left operand with no reshuffle.
pub struct PooledBackend {
    pub dk: usize,
    pub dv: usize,
    pub vocab: usize,
    pub heads: usize,
    pub layers: usize,
    kind: TransitionKind,
    /// per-(layer, head) query/key/value embeddings, layer-major
    /// (index `l · heads + h`), (vocab, dk|dk|dv) each; keys L2-normalized
    eq: Vec<Mat>,
    ek: Vec<Mat>,
    ev: Vec<Mat>,
    /// output head, (vocab, layers·heads·dv): logits = O_cat @ W_o^T
    wo: Mat,
    /// per-layer position-dependent α/β/λ — the one gate source for
    /// prefill AND decode
    gates: Vec<GateTable>,
    /// chunked-prefill chunk size (power of two; 0 disables)
    prefill_chunk: usize,
    pool: StatePool,
    slots: Vec<Option<SeqState>>,
    free_slots: Vec<usize>,
    /// blocks reserved per live slot (admission accounting)
    reserved: Vec<usize>,
    reserved_total: usize,
    dec: BatchedDecoder,
    adv: BatchedAdvance,
    // step workspaces (reused across steps; logits are allocated per
    // step because the trait returns an owned Vec)
    q_buf: Vec<f32>,
    o_buf: Vec<f32>,
    // prefill gather workspaces (reused across chunks: the stacked
    // per-head (k, v) embeddings and the chunk's α/β schedules)
    kc_buf: Vec<f32>,
    vc_buf: Vec<f32>,
    alpha_buf: Vec<f32>,
    beta_buf: Vec<f32>,
}

impl PooledBackend {
    /// Single-layer single-head backend with the default gates and a
    /// 16-token prefill chunk. `pool_blocks` bounds resident decode
    /// memory: admission reserves
    /// `layers · heads · blocks_for_steps(max_steps)` blocks per sequence
    /// against it.
    pub fn new(vocab: usize, dk: usize, dv: usize, pool_blocks: usize, seed: u64) -> PooledBackend {
        PooledBackend::with_config(vocab, 1, dk, dv, 16, pool_blocks, seed)
    }

    /// Single-layer Mamba-2 backend: `heads` attention heads and a
    /// `prefill_chunk`-token chunkwise prefill path (0 disables chunked
    /// prefill; the server then feeds prompts token-by-token).
    pub fn with_config(
        vocab: usize,
        heads: usize,
        dk: usize,
        dv: usize,
        prefill_chunk: usize,
        pool_blocks: usize,
        seed: u64,
    ) -> PooledBackend {
        PooledBackend::with_model_config(
            vocab,
            1,
            heads,
            TransitionKind::Mamba2,
            dk,
            dv,
            prefill_chunk,
            pool_blocks,
            seed,
        )
    }

    /// Fully-configured backend: `layers` parallel attention layers of
    /// `heads` heads each, under the `kind` state transition (see the
    /// type docs for the model layout). A single-layer Mamba-2 config
    /// reproduces the pre-multi-layer backend exactly (same RNG draws,
    /// same weights, same trajectories).
    #[allow(clippy::too_many_arguments)]
    pub fn with_model_config(
        vocab: usize,
        layers: usize,
        heads: usize,
        kind: TransitionKind,
        dk: usize,
        dv: usize,
        prefill_chunk: usize,
        pool_blocks: usize,
        seed: u64,
    ) -> PooledBackend {
        assert!(layers >= 1, "at least one layer");
        assert!(heads >= 1, "at least one head");
        assert!(
            prefill_chunk == 0 || prefill_chunk.is_power_of_two(),
            "prefill chunk must be a power of two (or 0 to disable)"
        );
        let mut rng = Rng::new(seed);
        let mut eq = Vec::with_capacity(layers * heads);
        let mut ek = Vec::with_capacity(layers * heads);
        let mut ev = Vec::with_capacity(layers * heads);
        for _ in 0..layers * heads {
            eq.push(Mat::randn(vocab, dk, 1.0 / (dk as f32).sqrt(), &mut rng));
            let mut k = Mat::randn(vocab, dk, 1.0, &mut rng);
            for i in 0..vocab {
                let norm = crate::tensor::ops::l2_norm(k.row(i)).max(1e-6);
                for x in k.row_mut(i) {
                    *x /= norm;
                }
            }
            ek.push(k);
            ev.push(Mat::randn(vocab, dv, 1.0, &mut rng));
        }
        let wo = Mat::randn(vocab, layers * heads * dv, 1.0 / ((layers * heads * dv) as f32).sqrt(), &mut rng);
        // default schedule per layer: fixed α, λ^(l) = 2^-l — coarser
        // levels matter less; wide enough for any practical position
        // (clamped past the table by level_weight)
        let gates = GateTable::fixed(0.97, (0..24).map(|l| 0.5f32.powi(l)).collect());
        PooledBackend {
            dk,
            dv,
            vocab,
            heads,
            layers,
            kind,
            eq,
            ek,
            ev,
            wo,
            gates: vec![gates; layers],
            prefill_chunk,
            pool: StatePool::new(dk * dv, pool_blocks),
            slots: Vec::new(),
            free_slots: Vec::new(),
            reserved: Vec::new(),
            reserved_total: 0,
            dec: BatchedDecoder::new(),
            adv: BatchedAdvance::new(),
            q_buf: Vec::new(),
            o_buf: Vec::new(),
            kc_buf: Vec::new(),
            vc_buf: Vec::new(),
            alpha_buf: Vec::new(),
            beta_buf: Vec::new(),
        }
    }

    /// The shared state pool (inspection: in_use/peak/capacity).
    pub fn pool(&self) -> &StatePool {
        &self.pool
    }

    /// The state-transition family this backend's layers run.
    pub fn transition_kind(&self) -> TransitionKind {
        self.kind
    }

    /// Install a position-dependent gate schedule (per-token and/or
    /// per-head α/β/λ) on **every** layer. Both the chunkwise prefill
    /// path and the decode path read it, so the two ingestion paths
    /// cannot drift. Only meaningful before traffic runs.
    pub fn set_gates(&mut self, gates: GateTable) {
        self.gates = vec![gates; self.layers];
    }

    /// Install one layer's gate schedule (per-layer gate tables).
    pub fn set_layer_gates(&mut self, layer: usize, gates: GateTable) {
        self.gates[layer] = gates;
    }

    /// The gate schedule currently in force (layer 0's; see
    /// [`PooledBackend::layer_gates`] for the rest).
    pub fn gates(&self) -> &GateTable {
        &self.gates[0]
    }

    /// One layer's gate schedule.
    pub fn layer_gates(&self, layer: usize) -> &GateTable {
        &self.gates[layer]
    }

    /// Number of sequences currently mid-prefill (engine states resident
    /// outside the pool).
    pub fn prefilling(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|s| matches!(s, SeqState::Prefilling(_)))
            .count()
    }

    /// Flip a prefilling slot to decode mode: seal every layer's engine
    /// at its chunk boundary and export every (layer, head) into pool
    /// blocks through the bridge. No-op for slots already decoding.
    fn ensure_decoding(&mut self, slot: SeqSlot) -> Result<()> {
        if matches!(self.slots[slot.0], Some(SeqState::Decoding(_))) {
            return Ok(());
        }
        let Some(SeqState::Prefilling(mut engines)) = self.slots[slot.0].take() else {
            bail!("step row for a free slot");
        };
        let mut seqs = Vec::with_capacity(self.layers * self.heads);
        for eng in engines.iter_mut() {
            eng.finish();
            for h in 0..self.heads {
                match export_prefill_head(eng, h, &mut self.pool) {
                    Ok(s) => seqs.push(s),
                    Err(_) => {
                        // roll back the states already exported;
                        // unreachable under admission reservation, so
                        // surface loudly
                        for mut s in seqs {
                            s.release(&mut self.pool);
                        }
                        bail!("state pool exhausted during prefill export (reservation bug?)");
                    }
                }
            }
        }
        self.slots[slot.0] = Some(SeqState::Decoding(seqs));
        Ok(())
    }

    /// Gather one layer's chunk inputs — the stacked per-head `(k, v)`
    /// embedding rows and the head-major per-(head, token) α/β gate
    /// entries — into the caller's buffers (cleared first). THE one
    /// gather for both the serving path ([`DecodeBackend::prefill_chunk`])
    /// and the oracle replay ([`PooledBackend::oracle_decode_logits`]),
    /// so the two ingest bitwise-identical engine inputs by construction.
    fn gather_chunk_inputs(
        &self,
        layer: usize,
        tokens: &[i32],
        pos: usize,
        kc: &mut Vec<f32>,
        vc: &mut Vec<f32>,
        alpha: &mut Vec<f32>,
        beta: &mut Vec<f32>,
    ) {
        let (heads, vocab) = (self.heads, self.vocab);
        kc.clear();
        vc.clear();
        alpha.clear();
        beta.clear();
        for h in 0..heads {
            for (j, &tok) in tokens.iter().enumerate() {
                let ti = tok_index(tok, vocab);
                kc.extend_from_slice(self.ek[layer * heads + h].row(ti));
                vc.extend_from_slice(self.ev[layer * heads + h].row(ti));
                alpha.push(self.gates[layer].alpha_h(h, pos + j));
                beta.push(self.gates[layer].beta_h(h, pos + j));
            }
        }
    }

    /// The chunkwise-prefill position boundary for a `prompt_len`-token
    /// prompt: the server ingests full chunks while at least one chunk
    /// *plus the final prompt token the decode step needs* remains, so
    /// prefill covers positions `[0, boundary)` and the decode step feeds
    /// `[boundary, …)`.
    pub fn prefill_boundary(&self, prompt_len: usize) -> usize {
        let c = self.prefill_chunk;
        let mut pe = 0;
        if c > 0 {
            while pe + c < prompt_len {
                pe += c;
            }
        }
        pe
    }

    /// Per-sequence **oracle replay** of one request's full serving
    /// trajectory, on Mat-backed [`FenwickState`]s instead of the pool:
    /// the prompt's chunkwise span re-ingests through fresh per-layer
    /// [`PrefillEngine`]s (identical code and inputs as the serving path,
    /// so identical floats) and exports into `FenwickState::import_levels`
    /// — the Mat-backed mirror of the pool bridge — then every decode row
    /// steps token-by-token. Returns `(position, logits)` for every row
    /// the serving engine would feed through [`DecodeBackend::step`].
    ///
    /// `fed` is the exact token stream the server fed: the prompt followed
    /// by the sampled tokens except the last (which is never fed back).
    /// Bit-exactness with the pooled serving path — batched advance,
    /// batched read, batched logits GEMM, for any bucketing/scheduling —
    /// is the serving-trace differential property (`coordinator::trace`).
    pub fn oracle_decode_logits(&self, prompt_len: usize, fed: &[i32]) -> Vec<(usize, Vec<f32>)> {
        assert!(prompt_len >= 1 && prompt_len <= fed.len(), "fed must cover the prompt");
        let (layers, heads, dk, dv, vocab) = (self.layers, self.heads, self.dk, self.dv, self.vocab);
        let pe = self.prefill_boundary(prompt_len);
        let c = self.prefill_chunk;
        // 1) chunkwise prefill span, per layer (same engine code as
        //    `prefill_chunk`; the gathers below copy the same embedding
        //    rows and gate entries, so the inputs are bitwise identical)
        let mut states: Vec<FenwickState> = Vec::with_capacity(layers * heads);
        if pe > 0 {
            let mut engines: Vec<PrefillEngine> =
                (0..layers).map(|_| PrefillEngine::new(heads, dk, dv, c)).collect();
            let (mut kc, mut vc, mut alpha, mut beta) =
                (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            for (l, eng) in engines.iter_mut().enumerate() {
                let mut pos = 0;
                while pos < pe {
                    let tokens = &fed[pos..pos + c];
                    self.gather_chunk_inputs(l, tokens, pos, &mut kc, &mut vc, &mut alpha, &mut beta);
                    match self.kind {
                        TransitionKind::Mamba2 => eng.ingest_chunk_mamba2(&kc, &vc, &alpha, None),
                        TransitionKind::Gdn => eng.ingest_chunk_gdn(&kc, &vc, &alpha, &beta),
                    }
                    pos += c;
                }
                eng.finish();
                for h in 0..heads {
                    states.push(FenwickState::import_levels(dk, dv, pe, &eng.export_head(h)));
                }
            }
        } else {
            states = (0..layers * heads).map(|_| FenwickState::new(dk, dv)).collect();
        }
        // 2) decode rows, token by token
        let mut out = Vec::with_capacity(fed.len() - pe);
        let mut o_cat = vec![0.0f32; layers * heads * dv];
        for (p, &tok) in fed.iter().enumerate().skip(pe) {
            let ti = tok_index(tok, vocab);
            for l in 0..layers {
                for h in 0..heads {
                    let e = l * heads + h;
                    let alpha = self.gates[l].alpha_h(h, p);
                    let (ws, tr) = match self.kind {
                        TransitionKind::Mamba2 => (1.0, Transition::Decay(alpha)),
                        TransitionKind::Gdn => {
                            let beta = self.gates[l].beta_h(h, p);
                            (beta, Transition::GatedHouseholder { alpha, beta, k: self.ek[e].row(ti) })
                        }
                    };
                    let o = states[e].step(
                        self.eq[e].row(ti),
                        self.ek[e].row(ti),
                        self.ev[e].row(ti),
                        ws,
                        tr,
                        self.gates[l].lambda_h(h, p),
                    );
                    o_cat[e * dv..(e + 1) * dv].copy_from_slice(&o);
                }
            }
            let mut logits = vec![0.0f32; vocab];
            tensor::gemm_nt_into(1, layers * heads * dv, vocab, &o_cat, &self.wo.data, &mut logits, false);
            out.push((p, logits));
        }
        out
    }
}

/// Clamp a sampled/user token into embedding range.
#[inline]
fn tok_index(tok: i32, vocab: usize) -> usize {
    (tok.max(0) as usize).min(vocab - 1)
}

impl DecodeBackend for PooledBackend {
    fn admit(&mut self, max_steps: usize) -> Result<SeqSlot, AdmitError> {
        let need = self.layers * self.heads * blocks_for_steps(max_steps.max(1));
        if need > self.pool.capacity() {
            return Err(AdmitError::TooLarge);
        }
        if self.reserved_total + need > self.pool.capacity() {
            return Err(AdmitError::Exhausted);
        }
        self.reserved_total += need;
        let idx = match self.free_slots.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.reserved.push(0);
                self.slots.len() - 1
            }
        };
        // a fresh sequence starts in prefill mode when the backend has a
        // chunked-prefill path; with it disabled, decode states from step 0
        self.slots[idx] = Some(if self.prefill_chunk > 0 {
            SeqState::Prefilling(
                (0..self.layers)
                    .map(|_| PrefillEngine::new(self.heads, self.dk, self.dv, self.prefill_chunk))
                    .collect(),
            )
        } else {
            SeqState::Decoding(
                (0..self.layers * self.heads)
                    .map(|_| PooledFenwickState::new(self.dk, self.dv))
                    .collect(),
            )
        });
        self.reserved[idx] = need;
        Ok(SeqSlot(idx))
    }

    fn retire(&mut self, slot: SeqSlot) {
        match self.slots[slot.0].take().expect("retire of free slot") {
            SeqState::Prefilling(_) => {} // engine states live outside the pool
            SeqState::Decoding(seqs) => {
                for mut seq in seqs {
                    seq.release(&mut self.pool);
                }
            }
        }
        self.reserved_total -= self.reserved[slot.0];
        self.reserved[slot.0] = 0;
        self.free_slots.push(slot.0);
    }

    fn prefill_chunk_size(&self) -> usize {
        self.prefill_chunk
    }

    fn prefill_chunk(&mut self, slot: SeqSlot, tokens: &[i32], pos: usize) -> Result<()> {
        let c = self.prefill_chunk;
        if c == 0 {
            bail!("chunked prefill disabled on this backend");
        }
        if tokens.len() != c {
            bail!("prefill chunk must be exactly {c} tokens, got {}", tokens.len());
        }
        let (layers, heads, dk, dv) = (self.layers, self.heads, self.dk, self.dv);
        {
            let state = self.slots[slot.0].as_ref().expect("prefill of free slot");
            let SeqState::Prefilling(engines) = state else {
                bail!("prefill_chunk after decode began");
            };
            if engines[0].tokens() != pos {
                bail!("prefill position desync: engine at {}, chunk at {pos}", engines[0].tokens());
            }
        }
        for l in 0..layers {
            // per-(head, token) gates from the layer's shared schedule —
            // the same source the decode step reads — and the stacked
            // per-head (k, v) embeddings: (H, C, dk) / (H, C, dv), via
            // the one shared gather (`gather_chunk_inputs`) into
            // persistent workspaces, taken out for the call (this is the
            // serving hot path — no steady-state allocation)
            let mut kc = std::mem::take(&mut self.kc_buf);
            let mut vc = std::mem::take(&mut self.vc_buf);
            let mut alpha = std::mem::take(&mut self.alpha_buf);
            let mut beta = std::mem::take(&mut self.beta_buf);
            self.gather_chunk_inputs(l, tokens, pos, &mut kc, &mut vc, &mut alpha, &mut beta);
            debug_assert_eq!(kc.len(), heads * c * dk);
            debug_assert_eq!(vc.len(), heads * c * dv);
            let Some(SeqState::Prefilling(engines)) = self.slots[slot.0].as_mut() else {
                unreachable!("checked above")
            };
            match self.kind {
                TransitionKind::Mamba2 => {
                    engines[l].ingest_chunk_mamba2(&kc, &vc, &alpha, None)
                }
                TransitionKind::Gdn => {
                    engines[l].ingest_chunk_gdn(&kc, &vc, &alpha, &beta)
                }
            }
            self.kc_buf = kc;
            self.vc_buf = vc;
            self.alpha_buf = alpha;
            self.beta_buf = beta;
        }
        Ok(())
    }

    fn step(&mut self, _bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (layers, heads, dv, vocab) = (self.layers, self.heads, self.dv, self.vocab);
        // 0) rows arriving from chunked prefill flip to pooled decode
        //    states via the export bridge
        for &(slot, _, _) in rows {
            self.ensure_decoding(slot)?;
        }
        // 1) the pool-wide batched advance: every (sequence, layer, head)
        //    entry's merge + transition + sentinel write in ONE
        //    advance_bucket pass (level-major merges, one fused
        //    transition+write slab dispatch) — the per-sequence `advance`
        //    loop this replaces is the bench baseline in `decode_batched`.
        //    States are taken out of their slots for the duration so the
        //    pass can hold one &mut per entry without unsafe.
        let mut taken: Vec<(usize, Vec<PooledFenwickState>)> = Vec::with_capacity(n);
        for &(slot, _, _) in rows {
            let Some(SeqState::Decoding(seqs)) = self.slots[slot.0].take() else {
                unreachable!("ensured above")
            };
            taken.push((slot.0, seqs));
        }
        let mut jobs: Vec<AdvanceJob<'_>> = Vec::with_capacity(n * layers * heads);
        for &(_, tok, pos) in rows {
            let ti = tok_index(tok, vocab);
            for l in 0..layers {
                for h in 0..heads {
                    let e = l * heads + h;
                    let alpha = self.gates[l].alpha_h(h, pos as usize);
                    let k = self.ek[e].row(ti);
                    let (write_scale, transition) = match self.kind {
                        TransitionKind::Mamba2 => (1.0, Transition::Decay(alpha)),
                        TransitionKind::Gdn => {
                            let beta = self.gates[l].beta_h(h, pos as usize);
                            (beta, Transition::GatedHouseholder { alpha, beta, k })
                        }
                    };
                    jobs.push(AdvanceJob { k, v: self.ev[e].row(ti), write_scale, transition });
                }
            }
        }
        let refused = {
            let mut refs: Vec<&mut PooledFenwickState> =
                taken.iter_mut().flat_map(|(_, seqs)| seqs.iter_mut()).collect();
            debug_assert!(refs
                .iter()
                .zip(jobs.iter().enumerate())
                .all(|(s, (e, _))| s.t as i32 == rows[e / (layers * heads)].2));
            self.adv.advance_bucket(&mut self.pool, &mut refs, &jobs)
        };
        drop(jobs);
        for (slot_idx, seqs) in taken {
            self.slots[slot_idx] = Some(SeqState::Decoding(seqs));
        }
        if !refused.is_empty() {
            // unreachable under admission reservation; surface loudly
            bail!("state pool exhausted mid-step (reservation bug?)");
        }
        // 2) the batched read: every live level of every (sequence,
        //    layer, head) in the batch, one fused block-sparse GEMM over
        //    the pool slab. Entry order (seq-major, layer, head) makes
        //    o_buf row-major (n, L·H·dv) — the logits GEMM's left
        //    operand, no reshuffle.
        self.q_buf.clear();
        for &(_, tok, _) in rows {
            let ti = tok_index(tok, vocab);
            for e in 0..layers * heads {
                self.q_buf.extend_from_slice(self.eq[e].row(ti));
            }
        }
        self.o_buf.clear();
        self.o_buf.resize(n * layers * heads * dv, 0.0);
        {
            let mut seq_refs: Vec<&PooledFenwickState> = Vec::with_capacity(n * layers * heads);
            let mut lambdas: Vec<&[f32]> = Vec::with_capacity(n * layers * heads);
            for &(slot, _, pos) in rows {
                let Some(SeqState::Decoding(seqs)) = self.slots[slot.0].as_ref() else {
                    unreachable!("ensured above")
                };
                for l in 0..layers {
                    for h in 0..heads {
                        seq_refs.push(&seqs[l * heads + h]);
                        lambdas.push(self.gates[l].lambda_h(h, pos as usize));
                    }
                }
            }
            self.dec
                .read_batch(&self.pool, &seq_refs, &self.q_buf, &lambdas, &mut self.o_buf);
        }
        // 3) whole-batch logits in one GEMM: (n, L·H·dv) @ (vocab, L·H·dv)^T
        let mut logits = vec![0.0f32; n * vocab];
        tensor::gemm_nt_into(n, layers * heads * dv, vocab, &self.o_buf, &self.wo.data, &mut logits, false);
        Ok(logits)
    }

    fn state_bytes(&self) -> usize {
        let prefill: usize = self
            .slots
            .iter()
            .flatten()
            .map(|s| match s {
                SeqState::Prefilling(engines) => engines.iter().map(|e| e.state_bytes()).sum(),
                SeqState::Decoding(_) => 0,
            })
            .sum();
        self.pool.in_use() * self.pool.block_elems() * 4 + prefill
    }
}
