//! Decode execution backends behind the serving engine.
//!
//! [`DecodeServer`](super::server::DecodeServer) owns queueing, batching,
//! sampling, and retirement; *how* a batch of (token, position) rows is
//! stepped — and how per-sequence state is held — is a [`DecodeBackend`]:
//!
//! - [`PjrtBackend`]: the AOT path. Per-sequence dense state stacks are
//!   gathered into batched PJRT buffers, the compiled `decode_step`
//!   executes, states scatter back. Admission never backpressures (dense
//!   stacks are host `Vec`s).
//! - [`PooledBackend`]: the pure-Rust pooled path (this PR's engine). A
//!   single-layer log-linear attention LM whose per-sequence Fenwick
//!   states live in a shared [`StatePool`]; each step is matmul-rich —
//!   one [`BatchedDecoder::read_batch`] block-sparse GEMM for every live
//!   level of every sequence at once, then one `O @ W_o^T` GEMM for the
//!   whole batch's logits. [`DecodeBackend::admit`] reserves
//!   `blocks_for_steps(max_steps)` pool blocks per sequence and returns
//!   [`AdmitError::Exhausted`] when the pool can't hold another sequence
//!   — the backpressure signal the server's admission loop honors by
//!   leaving requests queued.

use anyhow::{bail, Result};

use crate::runtime::{ModelHandle, Runtime};
use crate::state::pool::StatePool;
use crate::state::pooled::{blocks_for_steps, BatchedDecoder, PooledFenwickState};
use crate::state::Transition;
use crate::tensor::{self, Mat};
use crate::util::Rng;

/// Backend-side handle for one admitted sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqSlot(pub usize);

/// Why admission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// No resources *right now* — retry once running sequences retire
    /// (the batcher keeps the request queued).
    Exhausted,
    /// The request can never fit this backend (e.g. needs more state
    /// blocks than the whole pool holds) — reject it.
    TooLarge,
}

/// One decode execution engine (state storage + step function).
pub trait DecodeBackend {
    /// Reserve resources for a sequence running at most `max_steps`
    /// decode steps; returns the slot to pass to [`DecodeBackend::step`].
    fn admit(&mut self, max_steps: usize) -> Result<SeqSlot, AdmitError>;

    /// Release a sequence's resources.
    fn retire(&mut self, slot: SeqSlot);

    /// Execute one decode step for `rows` of (slot, token, position) in a
    /// `bucket`-sized batch (`rows.len() <= bucket`; padding, if the
    /// backend needs fixed shapes, is backend-internal). Returns logits
    /// `(rows.len(), vocab)` row-major.
    fn step(&mut self, bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>>;

    /// Resident decode-state bytes right now (peak accounting).
    fn state_bytes(&self) -> usize;
}

// ---------------------------------------------------------------------------
// PJRT (AOT artifact) backend
// ---------------------------------------------------------------------------

/// The compiled-artifact backend: dense per-layer state stacks per
/// sequence, batched through the AOT `decode_step` executables.
pub struct PjrtBackend {
    model: ModelHandle,
    state_numels: Vec<usize>,
    dense_state_bytes_per_seq: usize,
    /// per-slot per-layer flat states (None = free slot)
    slots: Vec<Option<Vec<Vec<f32>>>>,
    free_slots: Vec<usize>,
}

impl PjrtBackend {
    /// Compile the decode executables for every bucket up front.
    pub fn new(rt: &Runtime, mut model: ModelHandle, buckets: &[usize]) -> Result<PjrtBackend> {
        for &b in buckets {
            model.ensure_decode(rt, b)?;
        }
        let state_numels: Vec<usize> = model
            .manifest
            .state_shapes
            .iter()
            .map(|s| s.iter().product())
            .collect();
        let dense = state_numels.iter().sum::<usize>() * 4;
        Ok(PjrtBackend {
            model,
            state_numels,
            dense_state_bytes_per_seq: dense,
            slots: Vec::new(),
            free_slots: Vec::new(),
        })
    }

    pub fn model(&self) -> &ModelHandle {
        &self.model
    }
}

impl DecodeBackend for PjrtBackend {
    fn admit(&mut self, _max_steps: usize) -> Result<SeqSlot, AdmitError> {
        let states: Vec<Vec<f32>> = self.state_numels.iter().map(|&n| vec![0.0f32; n]).collect();
        let idx = match self.free_slots.pop() {
            Some(i) => {
                self.slots[i] = Some(states);
                i
            }
            None => {
                self.slots.push(Some(states));
                self.slots.len() - 1
            }
        };
        Ok(SeqSlot(idx))
    }

    fn retire(&mut self, slot: SeqSlot) {
        assert!(self.slots[slot.0].take().is_some(), "retire of free slot");
        self.free_slots.push(slot.0);
    }

    fn step(&mut self, bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 || n > bucket {
            bail!("bad batch: {n} rows for bucket {bucket}");
        }
        let layers = self.state_numels.len();
        // gather into the fixed (bucket, ...) shapes the artifact expects
        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        let mut batched: Vec<Vec<f32>> = self
            .state_numels
            .iter()
            .map(|&numel| vec![0.0f32; bucket * numel])
            .collect();
        for (i, &(slot, tok, p)) in rows.iter().enumerate() {
            tokens[i] = tok;
            pos[i] = p;
            let st = self.slots[slot.0].as_ref().expect("live slot");
            for (l, layer) in st.iter().enumerate() {
                let numel = self.state_numels[l];
                batched[l][i * numel..(i + 1) * numel].copy_from_slice(layer);
            }
        }
        let mut logits = self.model.decode_step(bucket, &mut batched, &tokens, &pos)?;
        // scatter back
        for (i, &(slot, _, _)) in rows.iter().enumerate() {
            let st = self.slots[slot.0].as_mut().expect("live slot");
            for l in 0..layers {
                let numel = self.state_numels[l];
                st[l].copy_from_slice(&batched[l][i * numel..(i + 1) * numel]);
            }
        }
        // drop padding rows in place — no copy in the full-bucket case
        let vocab = logits.len() / bucket;
        logits.truncate(n * vocab);
        Ok(logits)
    }

    fn state_bytes(&self) -> usize {
        self.slots.iter().flatten().count() * self.dense_state_bytes_per_seq
    }
}

// ---------------------------------------------------------------------------
// Pooled pure-Rust backend
// ---------------------------------------------------------------------------

/// Pure-Rust pooled decode backend: a fixed-weight single-layer
/// log-linear Mamba-2-style LM (random embeddings + output head) whose
/// decode states live in a shared [`StatePool`]. Exists to serve real
/// token traffic through the batched Fenwick engine without PJRT — the
/// scheduler/backpressure testbed and the `decode_batched` bench engine.
pub struct PooledBackend {
    pub dk: usize,
    pub dv: usize,
    pub vocab: usize,
    /// query/key/value embeddings, (vocab, dk|dk|dv); keys L2-normalized
    eq: Mat,
    ek: Mat,
    ev: Mat,
    /// output head, (vocab, dv): logits = O @ W_o^T
    wo: Mat,
    /// per-level λ weights (decaying with level)
    lambda: Vec<f32>,
    /// per-step decay gate α
    alpha: f32,
    pool: StatePool,
    slots: Vec<Option<PooledFenwickState>>,
    free_slots: Vec<usize>,
    /// blocks reserved per live slot (admission accounting)
    reserved: Vec<usize>,
    reserved_total: usize,
    dec: BatchedDecoder,
    // step workspaces (reused across steps; logits are allocated per
    // step because the trait returns an owned Vec)
    q_buf: Vec<f32>,
    o_buf: Vec<f32>,
}

impl PooledBackend {
    /// `pool_blocks` bounds resident decode memory: admission reserves
    /// `blocks_for_steps(max_steps)` blocks per sequence against it.
    pub fn new(vocab: usize, dk: usize, dv: usize, pool_blocks: usize, seed: u64) -> PooledBackend {
        let mut rng = Rng::new(seed);
        let eq = Mat::randn(vocab, dk, 1.0 / (dk as f32).sqrt(), &mut rng);
        let mut ek = Mat::randn(vocab, dk, 1.0, &mut rng);
        for i in 0..vocab {
            let norm = crate::tensor::ops::l2_norm(ek.row(i)).max(1e-6);
            for x in ek.row_mut(i) {
                *x /= norm;
            }
        }
        let ev = Mat::randn(vocab, dv, 1.0, &mut rng);
        let wo = Mat::randn(vocab, dv, 1.0 / (dv as f32).sqrt(), &mut rng);
        // coarser levels matter less: λ^(l) = 2^-l, wide enough for any
        // practical position (clamped past the table by level_weight)
        let lambda: Vec<f32> = (0..24).map(|l| 0.5f32.powi(l)).collect();
        PooledBackend {
            dk,
            dv,
            vocab,
            eq,
            ek,
            ev,
            wo,
            lambda,
            alpha: 0.97,
            pool: StatePool::new(dk * dv, pool_blocks),
            slots: Vec::new(),
            free_slots: Vec::new(),
            reserved: Vec::new(),
            reserved_total: 0,
            dec: BatchedDecoder::new(),
            q_buf: Vec::new(),
            o_buf: Vec::new(),
        }
    }

    /// The shared state pool (inspection: in_use/peak/capacity).
    pub fn pool(&self) -> &StatePool {
        &self.pool
    }
}

/// Clamp a sampled/user token into embedding range.
#[inline]
fn tok_index(tok: i32, vocab: usize) -> usize {
    (tok.max(0) as usize).min(vocab - 1)
}

impl DecodeBackend for PooledBackend {
    fn admit(&mut self, max_steps: usize) -> Result<SeqSlot, AdmitError> {
        let need = blocks_for_steps(max_steps.max(1));
        if need > self.pool.capacity() {
            return Err(AdmitError::TooLarge);
        }
        if self.reserved_total + need > self.pool.capacity() {
            return Err(AdmitError::Exhausted);
        }
        self.reserved_total += need;
        let idx = match self.free_slots.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.reserved.push(0);
                self.slots.len() - 1
            }
        };
        self.slots[idx] = Some(PooledFenwickState::new(self.dk, self.dv));
        self.reserved[idx] = need;
        Ok(SeqSlot(idx))
    }

    fn retire(&mut self, slot: SeqSlot) {
        let mut seq = self.slots[slot.0].take().expect("retire of free slot");
        seq.release(&mut self.pool);
        self.reserved_total -= self.reserved[slot.0];
        self.reserved[slot.0] = 0;
        self.free_slots.push(slot.0);
    }

    fn step(&mut self, _bucket: usize, rows: &[(SeqSlot, i32, i32)]) -> Result<Vec<f32>> {
        let n = rows.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (dv, vocab) = (self.dv, self.vocab);
        // 1) per-sequence state update (merge + decay + write)
        for &(slot, tok, pos) in rows {
            let t = tok_index(tok, vocab);
            let k = self.ek.row(t);
            let v = self.ev.row(t);
            let seq = self.slots[slot.0].as_mut().expect("live slot");
            debug_assert_eq!(seq.t as i32, pos, "position desync");
            if seq
                .advance(&mut self.pool, k, v, 1.0, Transition::Decay(self.alpha))
                .is_err()
            {
                // unreachable under admission reservation; surface loudly
                bail!("state pool exhausted mid-step (reservation bug?)");
            }
        }
        // 2) the batched read: every live level of every sequence in the
        //    batch, one fused block-sparse GEMM over the pool slab
        self.q_buf.clear();
        for &(_, tok, _) in rows {
            let row = self.eq.row(tok_index(tok, vocab));
            self.q_buf.extend_from_slice(row);
        }
        self.o_buf.clear();
        self.o_buf.resize(n * dv, 0.0);
        {
            let seqs: Vec<&PooledFenwickState> = rows
                .iter()
                .map(|&(slot, _, _)| self.slots[slot.0].as_ref().expect("live slot"))
                .collect();
            let lambdas: Vec<&[f32]> = vec![&self.lambda[..]; n];
            self.dec
                .read_batch(&self.pool, &seqs, &self.q_buf, &lambdas, &mut self.o_buf);
        }
        // 3) whole-batch logits in one GEMM: (n, dv) @ (vocab, dv)^T
        let mut logits = vec![0.0f32; n * vocab];
        tensor::gemm_nt_into(n, dv, vocab, &self.o_buf, &self.wo.data, &mut logits, false);
        Ok(logits)
    }

    fn state_bytes(&self) -> usize {
        self.pool.in_use() * self.pool.block_elems() * 4
    }
}
