//! Differential serving-trace property harness (test-only).
//!
//! THE lockdown for the pooled serving engine: drive
//! [`DecodeServer`] + [`PooledBackend`] over **randomized traces** —
//! mixed prompt lengths (sub-chunk through multi-chunk, so chunkwise
//! prefill and token-by-token ingestion interleave), mixed `max_new`,
//! Mamba-2 *and* GDN transition modes, **sequential stacks of 1–3
//! layers** × 1–2 heads, shared / per-token / per-head gate tables,
//! randomized prefill chunk budgets, **prompt-scoring requests riding
//! along the generation traffic**, **shared prompt prefixes with the
//! copy-on-write prefix cache randomly armed** (repeat admissions adopt
//! cached chunk-boundary states; the squeezed pool LRU-evicts entries
//! mid-trace), **the state pool split into 1, 2, or 4 shards with the
//! layer-stack pipelining randomly armed** (sequences pin to one shard
//! at admission; shards advance concurrently on the resident thread
//! pool), and pool sizes squeezed near
//! exhaustion so admission backpressure fires mid-trace — capturing every
//! decode row's logits, then asserting them **bit-exact** against
//! [`PooledBackend::oracle_decode_logits`]: a per-sequence, Mat-backed
//! [`FenwickState`](crate::state::FenwickState) oracle replay of the same
//! request (chunkwise prefill span re-ingested through an identical
//! sequential [`crate::prefill::LayerStack`], then token-by-token,
//! layer-by-layer decode). Served [`ScoreResult`]s are likewise asserted
//! bit-exact against [`PooledBackend::oracle_score_logprobs`] — the
//! one-shot replay of the same chunk/tail scoring split.
//!
//! Traces also randomly arm the **bf16 state slab**
//! ([`crate::state::pool::Precision::Bf16`]): decode rows are then held
//! to the [`BF16_TRACE_TOL`] relative-error bar instead of bit-exactness
//! (storage narrowing is the one sanctioned divergence — docs/PRECISION.md
//! derives the bound), while scoring, which never touches the pool, stays
//! bit-exact. The pinned heavy grid runs in both precisions.
//!
//! Why bit-exactness is the right bar: every serving-side batching —
//! the pool-wide [`crate::state::BatchedAdvance`], the block-sparse
//! [`crate::state::BatchedDecoder`] read, the per-layer projection GEMMs,
//! the whole-batch logits GEMM — is built from the *same primitive ops in
//! the same per-entry order* as the per-sequence path, so any scheduling,
//! bucketing, interleaving, budget, or batch-composition effect on a
//! sequence's logits or log-probs is a bug this harness catches with zero
//! tolerance. (Note the per-token per-layer recurrent oracle also covers
//! the acceptance criterion directly: prompts shorter than one chunk take
//! the pure token-by-token path end to end.) Failures shrink (via
//! [`crate::util::prop`]) toward fewer requests and shorter prompts
//! before reporting.

use std::time::Duration;

use crate::coordinator::backend::{PooledBackend, TransitionKind};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::server::DecodeServer;
use crate::coordinator::{GenRequest, ScoreRequest, ScoreResult};
use crate::state::pool::Precision;
use crate::state::pooled::blocks_for_steps;
use crate::state::GateTable;
use crate::tensor::Mat;
use crate::util::prop::{check, Pair, UsizeIn};
use crate::util::Rng;

const VOCAB: usize = 24;

/// Relative-error bound for bf16-pool traces, against the f32 oracle
/// replay: `|got − want| / (1 + |want|) ≤ 0.05`. docs/PRECISION.md
/// derives the bound — per-step narrowing injects at most one unit
/// roundoff `u = 2⁻⁹` per stored element, the Fenwick merge tree
/// compounds ~`log₂ T + 2` narrowings per contribution, and the
/// projection/logits GEMMs amplify by the layer stack's modest condition
/// number; 0.05 covers the harness's deepest configuration (3 layers ×
/// 2 heads, multi-chunk prompts) with an order-of-magnitude margin.
/// F32-pool traces keep the zero-tolerance bar: `tol = None` below means
/// bit-exact.
const BF16_TRACE_TOL: f32 = 0.05;

/// Build a randomized single-head gate table (per-token α/λ, per-token β)
/// from `rng`.
fn random_head_table(rng: &mut Rng) -> GateTable {
    let rows = 48;
    let alpha: Vec<f32> = (0..rows).map(|_| rng.range_f32(0.85, 1.0)).collect();
    let beta: Vec<f32> = (0..rows).map(|_| rng.range_f32(0.1, 0.9)).collect();
    let lambda = Mat::rand_uniform(rows, 6, 0.05, 1.0, rng);
    GateTable::per_token(alpha, lambda).with_beta(beta)
}

/// Compare one request's captured serving logits against the
/// per-sequence oracle replay — THE differential assertion, shared by the
/// randomized property and the pinned heavy traces so both enforce the
/// identical contract. `tokens` are the request's sampled completions
/// (`fed` = prompt + all but the last, which is never fed back). `tol`
/// selects the comparison mode: `None` is the bit-exact bar (f32 pools —
/// every serving batching is the same primitive ops in the same order as
/// the oracle), `Some(bound)` the relative-error bar
/// `|got − want| / (1 + |want|) ≤ bound` (bf16 pools, where storage
/// narrowing is the one sanctioned divergence; see [`BF16_TRACE_TOL`]).
/// `Err` describes the first divergence.
fn compare_to_oracle(
    backend: &PooledBackend,
    prompt: &[i32],
    id: u64,
    tokens: &[i32],
    captured: &[(u64, usize, Vec<f32>)],
    tol: Option<f32>,
) -> Result<(), String> {
    let mut fed = prompt.to_vec();
    fed.extend_from_slice(&tokens[..tokens.len() - 1]);
    let oracle = backend.oracle_decode_logits(prompt.len(), &fed);
    let mut rows: Vec<(usize, &[f32])> = captured
        .iter()
        .filter(|(cid, _, _)| *cid == id)
        .map(|(_, pos, logits)| (*pos, &logits[..]))
        .collect();
    rows.sort_by_key(|&(pos, _)| pos);
    if rows.len() != oracle.len() {
        return Err(format!(
            "req {id}: {} captured decode rows, oracle replayed {}",
            rows.len(),
            oracle.len()
        ));
    }
    for ((got_pos, got), (want_pos, want)) in rows.iter().zip(oracle.iter()) {
        if got_pos != want_pos {
            return Err(format!("req {id}: row at pos {got_pos}, oracle at {want_pos}"));
        }
        match tol {
            None => {
                if *got != &want[..] {
                    let j = got.iter().zip(want.iter()).position(|(a, b)| a != b).unwrap();
                    return Err(format!(
                        "req {id}: logits not bit-exact at pos {got_pos} (vocab {j}: {} vs {})",
                        got[j], want[j]
                    ));
                }
            }
            Some(bound) => {
                for (j, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                    let rel = (g - w).abs() / (1.0 + w.abs());
                    if !(rel <= bound) {
                        return Err(format!(
                            "req {id}: logits out of tolerance at pos {got_pos} \
                             (vocab {j}: {g} vs {w}, rel {rel} > {bound})"
                        ));
                    }
                }
            }
        }
    }
    Ok(())
}

/// Compare served scoring results against the one-shot scoring oracle,
/// bit-for-bit.
fn compare_scores_to_oracle(
    backend: &PooledBackend,
    score_reqs: &[ScoreRequest],
    results: &[ScoreResult],
) -> Result<(), String> {
    if results.len() != score_reqs.len() {
        return Err(format!(
            "{} of {} score requests completed",
            results.len(),
            score_reqs.len()
        ));
    }
    for req in score_reqs {
        let Some(res) = results.iter().find(|r| r.id == req.id) else {
            return Err(format!("score req {} has no result", req.id));
        };
        let want = backend.oracle_score_logprobs(&req.tokens);
        if res.logprobs.len() != want.len() {
            return Err(format!(
                "score req {}: {} logprobs, oracle has {}",
                req.id,
                res.logprobs.len(),
                want.len()
            ));
        }
        if res.logprobs != want {
            let j = res
                .logprobs
                .iter()
                .zip(want.iter())
                .position(|(a, b)| a != b)
                .unwrap();
            return Err(format!(
                "score req {}: logprob not bit-exact at target {} ({} vs {})",
                req.id,
                j + 1,
                res.logprobs[j],
                want[j]
            ));
        }
    }
    Ok(())
}

/// One randomized trace: build a backend + server from the case, run the
/// traffic (generation + scoring) to completion, replay every request
/// through the per-sequence oracles, and compare bit-for-bit. Returns an
/// error description instead of panicking so the property harness can
/// shrink the case.
fn run_trace(seed: u64, nreq: usize, max_prompt: usize) -> Result<(), String> {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x7ACE);
    let kind = if rng.chance(0.5) { TransitionKind::Gdn } else { TransitionKind::Mamba2 };
    let layers = 1 + rng.below(3);
    let heads = 1 + rng.below(2);
    let dk = if rng.chance(0.5) { 4 } else { 8 };
    let dv = dk;
    let prefill_chunk = if rng.chance(0.7) { 4 } else { 0 };
    // the sharded substrate rides along on every trace: shard count and
    // layer-stack pipelining are drawn per case, and the differential bar
    // below is unchanged — sharding must be invisible in the bits (each
    // sequence's states live wholly in one shard and its per-layer op
    // order is the same as the unsharded path)
    let shards = [1usize, 2, 4][rng.below(3)];
    let pipelined = rng.chance(0.5);
    // the bf16 state slab rides along on some traces: decode rows are
    // then held to the relative-error bar instead of bit-exactness
    // (storage narrowing is the one sanctioned divergence); scoring never
    // touches the pool, so served log-probs stay bit-exact either way
    let bf16 = rng.chance(0.25);
    let tol = if bf16 { Some(BF16_TRACE_TOL) } else { None };

    // requests first, so the pool can be sized *near exhaustion*:
    // large enough for the biggest single request (no TooLarge), small
    // enough that the full offered load backpressures mid-trace. Some
    // requests draw from a small set of shared prefixes (system-prompt
    // style traffic), so the prefix-cache arm below gets genuine
    // cross-request boundary reuse.
    let shared: Vec<Vec<i32>> = (0..2)
        .map(|_| (0..1 + rng.below(max_prompt)).map(|_| rng.below(VOCAB) as i32).collect())
        .collect();
    let reqs: Vec<GenRequest> = (0..nreq)
        .map(|i| {
            let mut prompt: Vec<i32> =
                if rng.chance(0.4) { shared[rng.below(2)].clone() } else { Vec::new() };
            prompt.extend((0..1 + rng.below(max_prompt)).map(|_| rng.below(VOCAB) as i32));
            GenRequest { id: i as u64, prompt, max_new: 1 + rng.below(5) }
        })
        .collect();
    // scoring traffic rides along (only meaningful when the backend has
    // a scoring path — always true for PooledBackend)
    let nscore = rng.below(3);
    let score_reqs: Vec<ScoreRequest> = (0..nscore)
        .map(|i| ScoreRequest {
            id: 1000 + i as u64,
            tokens: (0..1 + rng.below(max_prompt + 3)).map(|_| rng.below(VOCAB) as i32).collect(),
        })
        .collect();
    let need = |r: &GenRequest| {
        layers * heads * blocks_for_steps((r.prompt.len() + r.max_new - 1).max(1))
    };
    let max_need = reqs.iter().map(&need).max().unwrap();
    let total_need: usize = reqs.iter().map(&need).sum();
    // every shard must fit the largest single reservation (sequences pin
    // to exactly one shard, so TooLarge is judged per shard) while the
    // aggregate still backpressures mid-trace
    let per_shard = max_need.max((total_need * 3 / 5).div_ceil(shards));
    let pool_blocks = per_shard * shards;

    let mut backend = PooledBackend::with_model_config(
        VOCAB,
        layers,
        heads,
        kind,
        dk,
        dv,
        prefill_chunk,
        pool_blocks,
        seed ^ 0xBACC,
    );
    backend.set_shards(shards);
    backend.set_pipelined(pipelined);
    if bf16 {
        backend.set_precision(Precision::Bf16);
    }
    // gate schedules: default fixed, shared per-token, or per-head
    // per-token — per layer
    for l in 0..layers {
        match rng.below(3) {
            0 => {} // keep the default fixed table
            1 => backend.set_layer_gates(l, random_head_table(&mut rng)),
            _ => backend.set_layer_gates(
                l,
                GateTable::per_head((0..heads).map(|_| random_head_table(&mut rng)).collect()),
            ),
        }
    }

    // the copy-on-write prefix cache rides along on some traces: repeat
    // and shared-prefix prompts then admit straight from cached
    // chunk-boundary states, and the squeezed pool forces LRU eviction
    // mid-trace — all still held to the bit-exact bar below
    let use_cache = prefill_chunk > 0 && rng.chance(0.5);
    if use_cache {
        backend.enable_prefix_cache();
    }

    let buckets = if rng.chance(0.5) { vec![4] } else { vec![1, 4, 8] };
    let policy = BatchPolicy::new(buckets, Duration::ZERO).with_prefill_budget(1 + rng.below(4));
    let mut srv = DecodeServer::with_backend(backend, policy);
    srv.enable_logit_capture();
    for r in &reqs {
        srv.submit(r.clone()).map_err(|e| format!("submit: {e}"))?;
    }
    for r in &score_reqs {
        srv.submit_score(r.clone()).map_err(|e| format!("submit_score: {e}"))?;
    }
    let results =
        DecodeServer::<PooledBackend>::results_by_id(srv.run_to_completion().map_err(|e| format!("serve: {e}"))?);
    let captured = srv.take_captured_logits();
    let score_results = srv.take_score_results();

    if results.len() != nreq {
        return Err(format!("{} of {nreq} requests completed", results.len()));
    }
    // after retirement the only blocks still out are the shard-local
    // prefix caches' refcounted boundary states; dropping the caches must
    // drain every shard to zero (any other residue is a leak)
    let held = srv.backend().pool().cache_blocks_held();
    if srv.backend().pool().in_use() != held {
        return Err(format!(
            "retirement leaked {} pool blocks ({held} held by the prefix caches)",
            srv.backend().pool().in_use()
        ));
    }
    srv.backend_mut().clear_prefix_cache();
    for s in 0..srv.backend().pool().n_shards() {
        if srv.backend().pool().shard(s).in_use() != 0 {
            return Err(format!(
                "shard {s} leaked {} pool blocks after cache clear",
                srv.backend().pool().shard(s).in_use()
            ));
        }
    }
    let ctx = |e: String| {
        format!(
            "{e} (kind {kind:?}, layers {layers}, heads {heads}, chunk {prefill_chunk}, \
             cache {use_cache}, pool {pool_blocks}, shards {shards}, pipelined {pipelined}, \
             bf16 {bf16})"
        )
    };
    for r in &reqs {
        let res = &results[&r.id];
        if res.tokens.len() != r.max_new {
            return Err(format!("req {}: {} of {} tokens", r.id, res.tokens.len(), r.max_new));
        }
        compare_to_oracle(srv.backend(), &r.prompt, r.id, &res.tokens, &captured, tol)
            .map_err(&ctx)?;
    }
    compare_scores_to_oracle(srv.backend(), &score_reqs, &score_results).map_err(&ctx)?;
    Ok(())
}

/// THE foregrounded differential property: serving-path logits (and
/// scoring log-probs) are bit-exact with the per-sequence oracle
/// replays, over randomized traces. Honors `PROP_SEED` (CI runs extra
/// seeds) and shrinks failing cases toward fewer requests / shorter
/// prompts.
#[test]
fn serving_trace_logits_match_oracle_replay_property() {
    check(
        "serving-trace differential",
        12,
        &Pair(UsizeIn(1, 10_000), Pair(UsizeIn(2, 6), UsizeIn(1, 13))),
        |&(seed, (nreq, max_prompt))| match run_trace(seed as u64, nreq, max_prompt) {
            Ok(()) => true,
            Err(e) => {
                eprintln!("trace(seed={seed}, nreq={nreq}, max_prompt={max_prompt}): {e}");
                false
            }
        },
    );
}

/// A pinned heavier trace per mode (belt to the property's braces): long
/// prompts over many chunks, bucket-8 batches, both transition families,
/// 3-layer sequential stacks × 2 heads, per-head gates, scoring traffic,
/// and a tight prefill budget — the configuration the acceptance
/// criteria name explicitly. Each mode runs over the full shard ×
/// pipelining grid ({1, 2, 4} shards × layer-wise / pipelined stack):
/// the rng is re-seeded per grid cell so every cell serves the *same*
/// requests against the *same* weights, and every cell is compared to
/// the same unsharded per-sequence oracle — so all six cells are
/// transitively bit-identical to each other, not merely each
/// self-consistent.
#[test]
fn serving_trace_differential_pinned_heavy_modes() {
    serving_trace_heavy_grid(Precision::F32);
}

/// The same pinned heavy traces on the bf16 state slab: every cell of the
/// shard × pipelining grid, both transition families (the pinned seeds 11
/// and 12 are the Mamba-2 and GDN bf16 tolerance anchors the PRECISION
/// docs cite), held to the [`BF16_TRACE_TOL`] relative-error bar against
/// the same f32 per-sequence oracle — with the same zero-leaked-blocks
/// drain at the end of every cell.
#[test]
fn serving_trace_differential_pinned_heavy_modes_bf16() {
    serving_trace_heavy_grid(Precision::Bf16);
}

fn serving_trace_heavy_grid(precision: Precision) {
    let tol = match precision {
        Precision::F32 => None,
        Precision::Bf16 => Some(BF16_TRACE_TOL),
    };
    for (seed, kind) in [(11u64, TransitionKind::Mamba2), (12, TransitionKind::Gdn)] {
        for shards in [1usize, 2, 4] {
            for pipelined in [false, true] {
                let grid =
                    format!("{kind:?}, shards {shards}, pipelined {pipelined}, {precision:?}");
                let mut rng = Rng::new(seed);
                let (layers, heads, dk, dv, chunk) = (3usize, 2usize, 8usize, 8usize, 4usize);
                let reqs: Vec<GenRequest> = (0..10)
                    .map(|i| GenRequest {
                        id: i as u64,
                        // request 0 is pinned multi-chunk (the
                        // prefill-chunks assert below must not depend on
                        // the draw); the rest mix sub-chunk, exact-chunk,
                        // and multi-chunk lengths
                        prompt: (0..if i == 0 { 17 } else { 1 + rng.below(19) })
                            .map(|_| rng.below(VOCAB) as i32)
                            .collect(),
                        max_new: 1 + rng.below(6),
                    })
                    .collect();
                let score_reqs: Vec<ScoreRequest> = (0..3)
                    .map(|i| ScoreRequest {
                        id: 1000 + i as u64,
                        tokens: (0..5 + i * 7).map(|_| rng.below(VOCAB) as i32).collect(),
                    })
                    .collect();
                let need = |r: &GenRequest| {
                    layers * heads * blocks_for_steps(r.prompt.len() + r.max_new - 1)
                };
                let total: usize = reqs.iter().map(&need).sum();
                let max_need = reqs.iter().map(&need).max().unwrap();
                // per shard: still squeezed (aggregate ~2/3 of offered
                // load, so backpressure fires mid-trace) but never below
                // the largest single reservation
                let per_shard = max_need.max(((total * 2) / 3).div_ceil(shards));
                let mut backend = PooledBackend::with_model_config(
                    VOCAB,
                    layers,
                    heads,
                    kind,
                    dk,
                    dv,
                    chunk,
                    per_shard * shards,
                    seed,
                );
                backend.set_shards(shards);
                backend.set_pipelined(pipelined);
                backend.set_precision(precision);
                for l in 0..layers {
                    backend.set_layer_gates(
                        l,
                        GateTable::per_head(
                            (0..heads).map(|_| random_head_table(&mut rng)).collect(),
                        ),
                    );
                }
                let policy = BatchPolicy::new(vec![8], Duration::ZERO).with_prefill_budget(3);
                let mut srv = DecodeServer::with_backend(backend, policy);
                srv.enable_logit_capture();
                for r in &reqs {
                    srv.submit(r.clone()).unwrap();
                }
                for r in &score_reqs {
                    srv.submit_score(r.clone()).unwrap();
                }
                let results =
                    DecodeServer::<PooledBackend>::results_by_id(srv.run_to_completion().unwrap());
                let captured = srv.take_captured_logits();
                let score_results = srv.take_score_results();
                assert!(
                    srv.stats.prefill_chunks > 0,
                    "heavy trace must exercise chunkwise prefill ({grid})"
                );
                assert!(
                    srv.stats.score_chunks > 0,
                    "heavy trace must exercise chunkwise scoring ({grid})"
                );
                assert_eq!(results.len(), reqs.len(), "{grid}");
                for r in &reqs {
                    let res = &results[&r.id];
                    if let Err(e) = compare_to_oracle(
                        srv.backend(),
                        &r.prompt,
                        r.id,
                        &res.tokens,
                        &captured,
                        tol,
                    ) {
                        panic!("{e} ({grid})");
                    }
                }
                if let Err(e) = compare_scores_to_oracle(srv.backend(), &score_reqs, &score_results)
                {
                    panic!("{e} ({grid})");
                }
                // zero leaked blocks per shard after the trace drains
                for s in 0..srv.backend().pool().n_shards() {
                    assert_eq!(
                        srv.backend().pool().shard(s).in_use(),
                        0,
                        "leak on shard {s} ({grid})"
                    );
                }
            }
        }
    }
}

/// Prefix-cache operating modes for the pinned shared-prefix trace.
#[derive(Debug, Clone, Copy)]
enum CacheMode {
    /// no prefix cache — baseline serving
    Disabled,
    /// cache on, pool sized for the full offered load: every repeat
    /// prompt admits from cached chunk-boundary states
    Enabled,
    /// cache on, pool squeezed to exactly the largest single
    /// reservation: any block the cache holds is excess that live
    /// sequences' advances and exports must reclaim, so LRU eviction
    /// fires throughout the trace. A broken eviction path cannot pass
    /// silently here — it surfaces as a pool-exhaustion serve error.
    ForcedEviction,
}

/// The prefix-cache lock: system-prompt-style traffic (requests drawn
/// from a few shared prefixes, then the same prompts re-offered) served
/// through the copy-on-write [`crate::state::PrefixCache`], held to the
/// same bit-exact oracle bar as the cold path in every cache mode. The
/// second wave's admissions adopt the chunk-boundary states the first
/// wave published, so decode rows produced *from cached state* are
/// compared against a full cold oracle replay of the same request.
fn run_shared_prefix_trace(seed: u64, kind: TransitionKind, mode: CacheMode) -> Result<(), String> {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xCAC4E);
    let (layers, heads, dk, dv, chunk) = (2usize, 2usize, 4usize, 4usize, 4usize);
    // shared prefixes: one sub-chunk-offset, one chunk-straddling, one
    // multi-chunk — all longer than a chunk, so every prompt has a
    // non-trivial cacheable boundary
    let prefixes: Vec<Vec<i32>> = [8usize, 13, 18]
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(VOCAB) as i32).collect())
        .collect();
    // wave 1: two requests per prefix with random suffixes (cold, they
    // publish boundaries); wave 2 re-offers every wave-1 prompt verbatim
    // under new ids (with the cache on, each admission is a full hit on
    // its twin's boundary entry)
    let wave1: Vec<GenRequest> = (0..6)
        .map(|i| {
            let mut prompt = prefixes[i % prefixes.len()].clone();
            prompt.extend((0..rng.below(5)).map(|_| rng.below(VOCAB) as i32));
            GenRequest { id: i as u64, prompt, max_new: 1 + rng.below(4) }
        })
        .collect();
    let wave2: Vec<GenRequest> = wave1
        .iter()
        .enumerate()
        .map(|(i, r)| GenRequest {
            id: 100 + i as u64,
            prompt: r.prompt.clone(),
            max_new: 1 + rng.below(4),
        })
        .collect();
    let need = |r: &GenRequest| {
        layers * heads * blocks_for_steps((r.prompt.len() + r.max_new - 1).max(1))
    };
    let max_need = wave1.iter().chain(wave2.iter()).map(&need).max().unwrap();
    let pool_blocks = match mode {
        CacheMode::ForcedEviction => max_need,
        _ => wave1.iter().chain(wave2.iter()).map(&need).sum::<usize>(),
    };
    let mut backend = PooledBackend::with_model_config(
        VOCAB,
        layers,
        heads,
        kind,
        dk,
        dv,
        chunk,
        pool_blocks,
        seed ^ 0xF00D,
    );
    for l in 0..layers {
        backend.set_layer_gates(
            l,
            GateTable::per_head((0..heads).map(|_| random_head_table(&mut rng)).collect()),
        );
    }
    if !matches!(mode, CacheMode::Disabled) {
        backend.enable_prefix_cache();
    }
    let policy = BatchPolicy::new(vec![1, 4], Duration::ZERO).with_prefill_budget(2);
    let mut srv = DecodeServer::with_backend(backend, policy);
    srv.enable_logit_capture();
    let mut finished = Vec::new();
    for wave in [&wave1, &wave2] {
        for r in wave.iter() {
            srv.submit(r.clone()).map_err(|e| format!("submit: {e}"))?;
        }
        finished.extend(srv.run_to_completion().map_err(|e| format!("serve: {e}"))?);
    }
    let results = DecodeServer::<PooledBackend>::results_by_id(finished);
    let captured = srv.take_captured_logits();
    if results.len() != wave1.len() + wave2.len() {
        return Err(format!("{} of 12 requests completed", results.len()));
    }
    match mode {
        CacheMode::Disabled => {
            if srv.stats.prefix_cache_hits != 0 || srv.stats.prefill_tokens_saved != 0 {
                return Err(format!(
                    "cache disabled but {} hits / {} tokens saved reported",
                    srv.stats.prefix_cache_hits, srv.stats.prefill_tokens_saved
                ));
            }
        }
        CacheMode::Enabled => {
            // wave 1 is all-cold (admitted together against an empty
            // cache); every wave-2 admission must hit
            if srv.stats.prefix_cache_hits < wave2.len() {
                return Err(format!(
                    "only {} of {} repeat admissions hit the prefix cache",
                    srv.stats.prefix_cache_hits,
                    wave2.len()
                ));
            }
            if srv.stats.prefill_tokens_saved == 0 {
                return Err("cache hits saved no prefill tokens".to_string());
            }
        }
        // hits are incidental under forced eviction (entries rarely
        // survive to the repeat) — bit-exactness and clean completion
        // are the bar
        CacheMode::ForcedEviction => {}
    }
    for r in wave1.iter().chain(wave2.iter()) {
        let res = results
            .get(&r.id)
            .ok_or_else(|| format!("req {} has no result", r.id))?;
        if res.tokens.len() != r.max_new {
            return Err(format!("req {}: {} of {} tokens", r.id, res.tokens.len(), r.max_new));
        }
        compare_to_oracle(srv.backend(), &r.prompt, r.id, &res.tokens, &captured, None)?;
    }
    // the cache's refcounted boundary states are the only blocks allowed
    // to outlive retirement; clearing the cache must drain the pool
    let held = srv.backend().prefix_cache().map_or(0, |c| c.blocks_held());
    if srv.backend().pool().in_use() != held {
        return Err(format!(
            "retirement leaked {} pool blocks ({held} held by the prefix cache)",
            srv.backend().pool().in_use()
        ));
    }
    srv.backend_mut().clear_prefix_cache();
    if srv.backend().pool().in_use() != 0 {
        return Err(format!(
            "prefix cache leaked {} pool blocks on clear",
            srv.backend().pool().in_use()
        ));
    }
    Ok(())
}

/// Pinned shared-prefix traces across every cache mode × transition
/// family: serving from cached copy-on-write prefix states is bit-exact
/// with the cold per-sequence oracle replay whether the cache is off, on
/// with room to keep its entries, or thrashing under forced LRU
/// eviction.
#[test]
fn shared_prefix_trace_bit_exact_across_cache_modes() {
    for kind in [TransitionKind::Mamba2, TransitionKind::Gdn] {
        for mode in [CacheMode::Disabled, CacheMode::Enabled, CacheMode::ForcedEviction] {
            if let Err(e) = run_shared_prefix_trace(21, kind, mode) {
                panic!("{e} ({kind:?}, {mode:?})");
            }
        }
    }
}

/// Regression lock for the padded-bucket vocab contract: five ready rows
/// fall *strictly between* the configured bucket sizes {2, 8}, so (with
/// a zero batching wait) every decode step runs in an 8-wide bucket with
/// three rows of padding. The server must slice the returned logits with
/// the backend-reported width
/// ([`crate::coordinator::backend::DecodeBackend::vocab`]) rather than
/// deriving it as `logits.len() / ready` — with padded buckets those
/// differ whenever a backend returns bucket-shaped output, and the old
/// derivation sliced every row after the first from the wrong offsets.
/// All five prompts are sub-chunk and the prefill budget covers them in
/// one cycle, so the cohort enters decode together and stays in lockstep
/// (equal `max_new`): `ready` is exactly 5 on every decode step.
/// Bit-exactness against the per-sequence oracle is the assertion.
#[test]
fn trace_ready_rows_strictly_between_bucket_sizes() {
    let mut rng = Rng::new(31);
    let (layers, heads, dk, dv, chunk) = (2usize, 2usize, 4usize, 4usize, 4usize);
    let reqs: Vec<GenRequest> = (0..5)
        .map(|i| GenRequest {
            id: i as u64,
            prompt: (0..3).map(|_| rng.below(VOCAB) as i32).collect(),
            max_new: 4,
        })
        .collect();
    let need = |r: &GenRequest| layers * heads * blocks_for_steps(r.prompt.len() + r.max_new - 1);
    let pool_blocks: usize = reqs.iter().map(&need).sum();
    let mut backend = PooledBackend::with_model_config(
        VOCAB,
        layers,
        heads,
        TransitionKind::Mamba2,
        dk,
        dv,
        chunk,
        pool_blocks,
        31,
    );
    for l in 0..layers {
        backend.set_layer_gates(l, random_head_table(&mut rng));
    }
    let policy = BatchPolicy::new(vec![2, 8], Duration::ZERO).with_prefill_budget(32);
    let mut srv = DecodeServer::with_backend(backend, policy);
    srv.enable_logit_capture();
    for r in &reqs {
        srv.submit(r.clone()).unwrap();
    }
    let results = DecodeServer::<PooledBackend>::results_by_id(srv.run_to_completion().unwrap());
    let captured = srv.take_captured_logits();
    assert_eq!(results.len(), reqs.len());
    for r in &reqs {
        let res = &results[&r.id];
        assert_eq!(res.tokens.len(), r.max_new, "req {}", r.id);
        if let Err(e) =
            compare_to_oracle(srv.backend(), &r.prompt, r.id, &res.tokens, &captured, None)
        {
            panic!("{e}");
        }
    }
    assert_eq!(srv.backend().pool().in_use(), 0, "leak");
}
