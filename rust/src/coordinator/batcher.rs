//! Bucketed dynamic batching.
//!
//! Decode artifacts are AOT-compiled per batch size (e.g. {1, 4, 8}), so
//! the batcher's job is: given `ready` runnable sequences, pick the
//! artifact bucket to run next — the largest bucket that fills, or, after
//! `max_wait`, the smallest bucket that covers what's waiting (padding
//! idle rows). Pure logic, property-tested; the server owns the clock.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// available batch sizes, ascending (must be non-empty)
    pub buckets: Vec<usize>,
    /// how long to hold out for a fuller bucket
    pub max_wait: Duration,
    /// flop budget for prompt ingestion: at most this many prefill
    /// chunks (generation prompts + scoring work units combined) advance
    /// per engine step, round-robin fair across sequences — so many
    /// concurrent long prompts cannot crowd out decode latency. Each
    /// sequence still advances at most one chunk per step (chunk-level
    /// latency fairness); the budget caps the *total*.
    pub prefill_budget: usize,
}

impl BatchPolicy {
    pub fn new(mut buckets: Vec<usize>, max_wait: Duration) -> BatchPolicy {
        assert!(!buckets.is_empty());
        buckets.sort_unstable();
        buckets.dedup();
        // default budget: one largest-bucket's worth of chunk work per
        // step — prompt ingestion may cost about as much as the decode
        // batch it rides along, no more
        let prefill_budget = *buckets.last().unwrap();
        BatchPolicy { buckets, max_wait, prefill_budget }
    }

    /// Override the per-step prefill chunk budget (≥ 1).
    pub fn with_prefill_budget(mut self, budget: usize) -> BatchPolicy {
        assert!(budget >= 1, "a zero budget would starve prompt ingestion");
        self.prefill_budget = budget;
        self
    }

    /// Decide the bucket for `ready` runnable sequences. `waited` is the
    /// age of the oldest waiting item. Returns None to keep waiting.
    ///
    /// Policy: run the largest bucket immediately when it fills; otherwise
    /// hold out up to `max_wait`, then run the smallest bucket that COVERS
    /// everything waiting (padding idle rows) so no request is left behind.
    pub fn plan(&self, ready: usize, waited: Duration) -> Option<usize> {
        if ready == 0 {
            return None;
        }
        let largest = *self.buckets.last().unwrap();
        if ready >= largest {
            return Some(largest);
        }
        if waited < self.max_wait {
            return None;
        }
        Some(*self.buckets.iter().find(|&&b| b >= ready).unwrap_or(&largest))
    }
}

/// FIFO request queue with arrival timestamps (per-sequence fairness:
/// strictly in arrival order, never starved).
#[derive(Debug)]
pub struct RequestQueue<T> {
    items: VecDeque<(T, Instant)>,
}

impl<T> Default for RequestQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> RequestQueue<T> {
    pub fn new() -> Self {
        RequestQueue { items: VecDeque::new() }
    }

    pub fn push(&mut self, item: T) {
        self.items.push_back((item, Instant::now()));
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn oldest_age(&self) -> Duration {
        self.items
            .front()
            .map(|(_, t)| t.elapsed())
            .unwrap_or(Duration::ZERO)
    }

    /// The oldest item, without removing it — admission control inspects
    /// a request's resource needs before committing to pop it (a refused
    /// request stays at the head, preserving FIFO order under
    /// backpressure).
    pub fn peek(&self) -> Option<&T> {
        self.items.front().map(|(item, _)| item)
    }

    /// Pop the oldest item.
    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front().map(|(item, _)| item)
    }

    /// Pop the oldest item together with its arrival timestamp, so the
    /// server can account queue-wait time in request latency (it grows
    /// exactly when admission backpressure or holds make it matter).
    pub fn pop_timed(&mut self) -> Option<(T, Instant)> {
        self.items.pop_front()
    }

    /// Pop up to `n` items in arrival order.
    pub fn take(&mut self, n: usize) -> Vec<T> {
        let n = n.min(self.items.len());
        (0..n).map(|_| self.items.pop_front().unwrap().0).collect()
    }

    /// Remove and return the oldest item matching `pred` (cancellation of
    /// a not-yet-admitted request), leaving arrival order of the rest
    /// intact.
    pub fn remove_first<F: FnMut(&T) -> bool>(&mut self, mut pred: F) -> Option<T> {
        let i = self.items.iter().position(|(item, _)| pred(item))?;
        self.items.remove(i).map(|(item, _)| item)
    }

    /// Whether any queued item matches `pred` (duplicate-id screening at
    /// submit time — the queue is part of the live-id set).
    pub fn any<F: FnMut(&T) -> bool>(&self, mut pred: F) -> bool {
        self.items.iter().any(|(item, _)| pred(item))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Pair, UsizeIn};

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(2))
    }

    #[test]
    fn fills_largest_bucket_immediately() {
        let p = policy();
        assert_eq!(p.plan(8, Duration::ZERO), Some(8));
        assert_eq!(p.plan(12, Duration::ZERO), Some(8));
    }

    #[test]
    fn holds_for_fuller_bucket_then_gives_up() {
        let p = policy();
        // 5 ready: bucket 4 fills, but largest is 8 -> wait...
        assert_eq!(p.plan(5, Duration::ZERO), None);
        // ...until max_wait, then run the smallest covering bucket (8,
        // padded) so nothing is left behind
        assert_eq!(p.plan(5, Duration::from_millis(3)), Some(8));
    }

    #[test]
    fn small_traffic_runs_padded_after_wait() {
        let p = policy();
        assert_eq!(p.plan(1, Duration::ZERO), None);
        assert_eq!(p.plan(1, Duration::from_millis(3)), Some(1));
        // 2 ready -> smallest covering bucket is 4 (padded)
        assert_eq!(p.plan(2, Duration::from_millis(3)), Some(4));
    }

    #[test]
    fn zero_ready_never_plans() {
        let p = policy();
        assert_eq!(p.plan(0, Duration::from_secs(10)), None);
    }

    #[test]
    fn plan_never_exceeds_largest_bucket_property() {
        let p = policy();
        check(
            "bucket bound",
            300,
            &Pair(UsizeIn(0, 100), UsizeIn(0, 10)),
            |&(ready, ms)| {
                match p.plan(ready, Duration::from_millis(ms as u64)) {
                    None => true,
                    Some(b) => p.buckets.contains(&b) && b <= 8,
                }
            },
        );
    }

    #[test]
    fn eventually_serves_everything_property() {
        // with waited >= max_wait and ready > 0, plan is always Some
        let p = policy();
        check("no starvation", 300, &UsizeIn(1, 64), |&ready| {
            p.plan(ready, Duration::from_millis(5)).is_some()
        });
    }

    #[test]
    fn queue_preserves_fifo_order() {
        let mut q = RequestQueue::new();
        for i in 0..10 {
            q.push(i);
        }
        assert_eq!(q.take(4), vec![0, 1, 2, 3]);
        assert_eq!(q.take(100), vec![4, 5, 6, 7, 8, 9]);
        assert!(q.is_empty());
    }
}
