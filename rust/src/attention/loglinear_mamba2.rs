//! Log-Linear Mamba-2 (paper §3.4): Mamba-2's scalar-gated linear
//! attention lifted with the hierarchical mask,
//! `O = (Q K^T ⊙ M^S ⊙ M^H) V`.
//!
//! Three forms:
//! - [`recurrent`]: the §3.2 Fenwick recurrence over `O(log T)` states.
//! - [`parallel`]: dense masked form via [`crate::hmatrix::QuasiH`].
//! - [`chunkwise`]: Algorithm 1 — intra-chunk dense H-masked attention +
//!   `O(log(T/C))`-level inter-chunk state passing (fused, one pass).
//!   This is the matmul-rich §3.5 form: per chunk, *three* GEMMs do all
//!   the heavy lifting (batched level read `Q_c S_cat`, local `Q_c K_c^T`,
//!   masked `P V_c`) plus one fused `K_c^T diag(w) V_c` state write —
//!   no per-token matvec loops anywhere.
//! - [`chunkwise_naive`]: the "Log-Linear Mamba-2 (naive)" baseline of
//!   Fig. 4 — one full Mamba-2-style masked state-passing sweep *per
//!   level*, for the E12 level-fusion ablation (same GEMM substrate, so
//!   the ablation isolates level fusion, not scalar-vs-GEMM).

use crate::fenwick;
use crate::tensor::{self, outer_acc, Mat};

use super::loglinear::{parallel_from_a, ChunkFenwick};

/// Token-granularity Fenwick recurrence (decode form). `O(log t)` live
/// states; per step: merge, decay, write sentinel, read with λ.
pub fn recurrent(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], lambda: &Mat) -> Mat {
    let (t_len, dk, dv) = (q.rows, q.cols, v.cols);
    let mut out = Mat::zeros(t_len, dv);
    // levels[0] = sentinel state, levels[m>=1] = bucket states.
    let nl = fenwick::num_levels(t_len.max(1));
    let mut levels: Vec<Option<Mat>> = vec![None; nl + 1];
    for t in 0..t_len {
        // 1) merge: buckets 0..=lssb(t) promote into level lssb(t)+1.
        if t > 0 {
            let l = fenwick::lssb(t) as usize;
            let mut merged: Option<Mat> = None;
            for s in levels.iter_mut().take(l + 1) {
                if let Some(m) = s.take() {
                    match merged {
                        None => merged = Some(m),
                        Some(ref mut acc) => acc.axpy(1.0, &m),
                    }
                }
            }
            if let Some(m) = merged {
                debug_assert!(levels[l + 1].is_none());
                levels[l + 1] = Some(m);
            }
        }
        // 2) decay all carried states by α_t.
        for s in levels.iter_mut().flatten() {
            s.scale_inplace(alpha[t]);
        }
        // 3) sentinel: fresh (k_t, v_t), no decay.
        let mut s0 = Mat::zeros(dk, dv);
        outer_acc(&mut s0, k.row(t), v.row(t), 1.0);
        levels[0] = Some(s0);
        // 4) read: o_t = Σ_ℓ λ_t^(ℓ) S^(ℓ)T q_t (fused, no temporaries).
        let orow = out.row_mut(t);
        for (l, s) in levels.iter().enumerate() {
            if let Some(s) = s {
                let lam = lambda.at(t, l);
                if lam == 0.0 {
                    continue;
                }
                s.matvec_t_acc(q.row(t), lam, orow);
            }
        }
    }
    out
}

/// Parallel form: `O = (Q K^T ⊙ QuasiH(α, λ)) V`.
pub fn parallel(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], lambda: &Mat) -> Mat {
    let mut a = q.matmul_nt(k);
    let t = q.rows;
    for i in 0..t {
        for j in i + 1..t {
            *a.at_mut(i, j) = 0.0;
        }
    }
    parallel_from_a(&a, alpha, lambda, v)
}

/// Algorithm 1, fused: one pass over chunks; per chunk the engine exposes
/// all `O(log(T/C))` level states at once so every level's contribution is
/// read with a single `Q_c @ S_cat` GEMM (the level-fusion optimization of
/// §3.5 — contrast [`chunkwise_naive`]). All per-chunk buffers are
/// workspaces reused across chunks.
pub fn chunkwise(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], lambda: &Mat, c: usize) -> Mat {
    assert!(c >= 1 && c.is_power_of_two(), "chunk size must be a power of two");
    let (t_len, dk, dv) = (q.rows, q.cols, v.cols);
    let lc = c.trailing_zeros() as usize; // log2(C): token level = lc + chunk level
    let mut out = Mat::zeros(t_len, dv);
    let mut eng = ChunkFenwick::new();
    // per-chunk workspaces, allocated once (chunks never exceed T rows)
    let cmax = c.min(t_len.max(1));
    let mut pbuf = vec![0.0f32; cmax * cmax];
    let mut dec_in = vec![0.0f32; cmax];
    let mut wscale = vec![0.0f32; cmax];
    let mut z = 0usize;
    let mut start = 0usize;
    while start < t_len {
        let end = (start + c).min(t_len);
        let len = end - start;
        eng.advance(z);

        // Local cumulative decay through position i.
        let mut acc = 1.0f64;
        for i in 0..len {
            acc *= alpha[start + i] as f64;
            dec_in[i] = acc as f32;
        }

        // Inter-chunk, batched: one GEMM over the concatenated level
        // states, folded with λ_t^(lc+m) · dec_in[t].
        eng.read_levels_into(q.rows_data(start, end), len, &mut out, start, |i, m| {
            lambda.at(start + i, lc + m) * dec_in[i]
        });

        // Intra-chunk: P = Q_c K_c^T (GEMM), masked in place by the decay
        // ratio and the local λ mask, then out += P V_c (masked GEMM).
        let p = &mut pbuf[..len * len];
        tensor::gemm_nt_into(len, dk, len, q.rows_data(start, end), k.rows_data(start, end), p, false);
        for i in 0..len {
            let prow = &mut p[i * len..(i + 1) * len];
            for (j, pij) in prow.iter_mut().enumerate() {
                if j > i {
                    *pij = 0.0;
                } else {
                    *pij *= (dec_in[i] / dec_in[j]) * lambda.at(start + i, fenwick::level_of(i, j));
                }
            }
        }
        tensor::gemm_sparse_rows(len, len, dv, p, v.rows_data(start, end), out.rows_data_mut(start, end), true);

        // Chunk state write: W_z = K_c^T diag(chunk_decay / dec_in) V_c
        // as one fused kernel into a recycled buffer.
        let chunk_decay = dec_in[len - 1];
        for j in 0..len {
            wscale[j] = chunk_decay / dec_in[j];
        }
        let mut w = eng.take_buffer(dk, dv);
        tensor::gemm_tn_diag_acc(
            len,
            dk,
            dv,
            &wscale[..len],
            k.rows_data(start, end),
            v.rows_data(start, end),
            &mut w.data,
        );
        // Transition carried states, then install the fresh one.
        eng.apply_transition(|s| s.scale_inplace(chunk_decay));
        eng.set_level0(w);

        z += 1;
        start = end;
    }
    out
}

/// The naive multi-level baseline (Fig. 4 "Log-Linear Mamba-2 (naive)"):
/// one independent Mamba-2-style masked inter-chunk sweep *per level*,
/// each re-reading Q and the chunk states. Same asymptotics and the same
/// GEMM substrate as [`chunkwise`], ~L× the memory traffic — the target
/// of the §3.5 level-fusion optimization.
pub fn chunkwise_naive(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], lambda: &Mat, c: usize) -> Mat {
    assert!(c >= 1 && c.is_power_of_two());
    let (t_len, dk, dv) = (q.rows, q.cols, v.cols);
    let lc = c.trailing_zeros() as usize;
    let nchunks = t_len.div_ceil(c);
    let mut out = Mat::zeros(t_len, dv);

    // Per-chunk decays and local cumulative decays.
    let mut dec_in = vec![0.0f32; t_len];
    let mut chunk_decay = vec![0.0f32; nchunks];
    for z in 0..nchunks {
        let (start, end) = (z * c, ((z + 1) * c).min(t_len));
        let mut acc = 1.0f64;
        for i in start..end {
            acc *= alpha[i] as f64;
            dec_in[i] = acc as f32;
        }
        chunk_decay[z] = acc as f32;
    }

    // Per-chunk states (own contribution only), fused K^T diag(w) V writes.
    let cmax = c.min(t_len.max(1));
    let mut wscale = vec![0.0f32; cmax];
    let states: Vec<Mat> = (0..nchunks)
        .map(|z| {
            let (start, end) = (z * c, ((z + 1) * c).min(t_len));
            let len = end - start;
            for j in 0..len {
                wscale[j] = chunk_decay[z] / dec_in[start + j];
            }
            let mut w = Mat::zeros(dk, dv);
            tensor::gemm_tn_diag_acc(
                len,
                dk,
                dv,
                &wscale[..len],
                k.rows_data(start, end),
                v.rows_data(start, end),
                &mut w.data,
            );
            w
        })
        .collect();

    // Intra-chunk (identical to the fused version).
    let mut pbuf = vec![0.0f32; cmax * cmax];
    for z in 0..nchunks {
        let (start, end) = (z * c, ((z + 1) * c).min(t_len));
        let len = end - start;
        let p = &mut pbuf[..len * len];
        tensor::gemm_nt_into(len, dk, len, q.rows_data(start, end), k.rows_data(start, end), p, false);
        for i in 0..len {
            let prow = &mut p[i * len..(i + 1) * len];
            for (j, pij) in prow.iter_mut().enumerate() {
                if j > i {
                    *pij = 0.0;
                } else {
                    *pij *= (dec_in[start + i] / dec_in[start + j])
                        * lambda.at(start + i, fenwick::level_of(i, j));
                }
            }
        }
        tensor::gemm_sparse_rows(len, len, dv, p, v.rows_data(start, end), out.rows_data_mut(start, end), true);
    }

    // Inter-chunk: one independent masked sweep per level — each level
    // re-reads Q and re-touches the states (the traffic the fused form
    // eliminates), but each read is still a GEMM.
    let max_level = fenwick::num_levels(nchunks.max(1));
    let mut rweight = vec![0.0f32; cmax];
    for m in 1..max_level {
        for z in 1..nchunks {
            if (z >> (m - 1)) & 1 != 1 {
                continue;
            }
            let bsize = 1usize << (m - 1);
            let bend = z & !(bsize - 1); // exclusive end of bucket (chunks)
            let bstart = bend - bsize;
            let mut combined = Mat::zeros(dk, dv);
            for cz in bstart..bend {
                // decay over full chunks cz+1 .. z-1
                let mut dec = 1.0f64;
                for d in chunk_decay.iter().take(z).skip(cz + 1) {
                    dec *= *d as f64;
                }
                combined.axpy(dec as f32, &states[cz]);
            }
            let (start, end) = (z * c, ((z + 1) * c).min(t_len));
            let len = end - start;
            for i in 0..len {
                rweight[i] = lambda.at(start + i, lc + m) * dec_in[start + i];
            }
            tensor::gemm_diag_acc(
                len,
                dk,
                dv,
                &rweight[..len],
                q.rows_data(start, end),
                &combined.data,
                out.rows_data_mut(start, end),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn parallel_equals_recurrent() {
        let mut rng = Rng::new(1);
        for &t in &[1usize, 2, 7, 16, 33, 64, 100] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &parallel(&x.q, &x.k, &x.v, &x.alpha, &x.lambda),
                &recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.lambda),
                1e-3,
                1e-3,
            );
        }
    }

    #[test]
    fn chunkwise_equals_recurrent() {
        let mut rng = Rng::new(2);
        for &(t, c) in &[(64usize, 8usize), (100, 16), (128, 32), (33, 4), (16, 16), (40, 1)] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            let oracle = recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.lambda);
            assert_close(
                &chunkwise(&x.q, &x.k, &x.v, &x.alpha, &x.lambda, c),
                &oracle,
                2e-3,
                2e-3,
            );
        }
    }

    #[test]
    fn naive_equals_fused() {
        let mut rng = Rng::new(3);
        for &(t, c) in &[(64usize, 8usize), (96, 16), (128, 16)] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &chunkwise_naive(&x.q, &x.k, &x.v, &x.alpha, &x.lambda, c),
                &chunkwise(&x.q, &x.k, &x.v, &x.alpha, &x.lambda, c),
                1e-3,
                1e-3,
            );
        }
    }

    #[test]
    fn lambda_zero_on_level_removes_its_bucket() {
        // Zeroing λ^(ℓ) for a given ℓ must remove exactly that bucket's
        // contribution — checked against a hand-built masked computation.
        let mut rng = Rng::new(4);
        let t = 32;
        let x = AttnInputs::random(t, 6, 6, &mut rng);
        let mut lam = x.lambda.clone();
        for i in 0..t {
            *lam.at_mut(i, 2) = 0.0; // kill level 2 (bucket size 2)
        }
        let o = recurrent(&x.q, &x.k, &x.v, &x.alpha, &lam);
        // direct masked computation
        let quasi = crate::hmatrix::QuasiH::new(&x.alpha, &lam).dense();
        let mut a = x.q.matmul_nt(&x.k);
        for i in 0..t {
            for j in i + 1..t {
                *a.at_mut(i, j) = 0.0;
            }
        }
        let expect = a.hadamard(&quasi).matmul(&x.v);
        assert_close(&o, &expect, 1e-3, 1e-3);
    }
}
