//! The attention zoo: pure-Rust reference implementations of every model
//! row in the paper's Table 1, each in up to three algorithmic forms.
//!
//! | model | recurrent | parallel (masked) | chunkwise | serving prefill | prompt scoring |
//! |-------|-----------|-------------------|-----------|-----------------|----------------|
//! | softmax attention           | (KV-cache decode) | ✓ `O(T^2)` | — | — | — |
//! | linear attention            | ✓ `O(T)` | ✓ | ✓ `O(T)` | — | — |
//! | Mamba-2 (scalar gate)       | ✓ | ✓ | ✓ (SSD) | — | — |
//! | DeltaNet                    | ✓ | ✓ (WY/UT) | ✓ | — | — |
//! | Gated DeltaNet              | ✓ | ✓ | ✓ | — | — |
//! | Log-Linear Mamba-2          | ✓ `O(log T)` state | ✓ | ✓ `O(T log T)` (Alg. 1) | ✓ head-batched | ✓ per-token log-probs |
//! | Log-Linear Gated DeltaNet   | ✓ `O(log T)` state | ✓ | ✓ | ✓ head-batched | ✓ per-token log-probs |
//! | *serving features* (log-linear rows) | per-token streaming + mid-flight cancel | — | — | CoW prefix-state cache (shared prefixes admitted from cached boundaries) | ✓ rides the same chunk outputs, rows streamed as chunks land |
//! | *sharded serving* (log-linear rows) | sharded state pool, sequences pinned at admission (**docs/SHARDING.md**) | — | — | per-shard prefix caches, cross-shard probe | pipelined L-layer decode, bit-exact at shards {1, 2, 4} × pipelining on/off |
//! | *observability* (whole serving stack) | zero-alloc span recorder ([`crate::obs`]) | — | — | per-chunk spans + GEMM flop accounting (O(log T) flops/token observable) | per-request timelines, TTFT/inter-token histograms, Chrome-trace export |
//! | *substrate precision* (whole serving stack) | bf16 state slab: 2 bytes/elem storage, f32 accumulate, reads within the **docs/PRECISION.md** tolerance (2× sequences per pool) | — | — | AVX2 SIMD microkernels (`--features simd`, runtime-detected), bit-exact vs the scalar oracle at f32 | log-probs bit-exact at any pool precision (scoring never touches the pool) |
//!
//! The serving-features row is the production surface over the two
//! log-linear rows: chunk-boundary hierarchies are snapshotted into a
//! copy-on-write [`crate::state::PrefixCache`] over the
//! [`crate::state::pool::StatePool`] slab (repeat prompts skip the
//! cached span's prefill entirely; LRU eviction returns blocks under
//! pool pressure), and the decode server streams every sampled token as
//! it lands and cancels mid-flight requests with immediate block release
//! (`coordinator::server::DecodeServer::{take_stream_events, cancel}`).
//! The observability row is [`crate::obs`]: thread-affine ring-buffer
//! span recording over every serving stage (submit → admit → prefill
//! chunks → per-layer decode GEMMs → stream/cancel), kernel flop/byte
//! accounting hooked into the tensor GEMM dispatch, latency histograms
//! in `ServerStats`, and Chrome trace-event / per-request timeline
//! exporters — see **docs/OBSERVABILITY.md**. The sharded-serving row
//! is the scale-out substrate under both: the pool splits into
//! per-worker shards ([`crate::state::ShardedStatePool`]) that advance
//! concurrently on the resident thread pool, with the sequential layer
//! stack optionally pipelined per shard — bit-exact with the unsharded
//! engine by construction — see **docs/SHARDING.md**.
//!
//! *Serving prefill* is the head-batched, sequential-L-layer chunkwise
//! ingester of [`crate::prefill`] (state-only for generation prompts,
//! per-token outputs for layer stacking); *prompt scoring* is the
//! serving-side per-token log-prob workload built on those outputs
//! (`coordinator::backend::PooledBackend::score_chunk` /
//! `ScoreRequest` on the decode server) — the workload where the
//! O(T log T) prefill directly wins over token-by-token replay.
//!
//! The *recurrent* form is always the unambiguous ground truth; property
//! tests assert `recurrent == parallel == chunkwise` on random inputs.
//! These implementations serve four roles: correctness oracles for the
//! Pallas kernels (shared golden fixtures), the CPU substrate for the
//! Fig. 4 / Table 1 benchmark reproductions, the decode path of the
//! Rust-side serving demo, and — for the log-linear rows — the chunkwise
//! machinery behind the serving engine's **prompt prefill**
//! ([`crate::prefill`]): a state-only, H-head-batched form of the
//! chunkwise algorithm ingests prompts at `O(T log T)` and hands the
//! resulting hierarchy to the pooled decode path through the export
//! bridge, replacing token-by-token prompt ingestion.
//!
//! Conventions: single head; `q,k: (T, d_k)`, `v: (T, d_v)`; hidden state
//! `S: (d_k, d_v)` updated as `S ← transition(S) + k_t v_t^T` and read as
//! `o_t = S^T q_t`. Gates `α_t ∈ (0,1]`, delta strengths `β_t ∈ (0,1]`,
//! level weights `λ: (T, num_levels)`.

pub mod softmax;
pub mod linear;
pub mod mamba2;
pub mod deltanet;
pub mod gated_deltanet;
pub mod loglinear;
pub mod loglinear_mamba2;
pub mod loglinear_gdn;

use crate::tensor::Mat;
use crate::util::Rng;

/// A bundle of per-head inputs covering the needs of every variant.
#[derive(Debug, Clone)]
pub struct AttnInputs {
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
    /// decay gates α_t (Mamba-2 / GDN families)
    pub alpha: Vec<f32>,
    /// delta-rule strengths β_t (DeltaNet families)
    pub beta: Vec<f32>,
    /// level weights λ_t^(ℓ), shape (T, num_levels(T)) (log-linear families)
    pub lambda: Mat,
}

impl AttnInputs {
    /// Random inputs with well-conditioned ranges (gates bounded away from
    /// 0, unit-ish keys) for property tests and benches.
    pub fn random(t: usize, dk: usize, dv: usize, rng: &mut Rng) -> AttnInputs {
        let q = Mat::randn(t, dk, 1.0 / (dk as f32).sqrt(), rng);
        let mut k = Mat::randn(t, dk, 1.0, rng);
        // L2-normalize keys: standard for DeltaNet (keeps Householder
        // transitions contractive) and harmless elsewhere.
        for i in 0..t {
            let n = crate::tensor::ops::l2_norm(k.row(i)).max(1e-6);
            for x in k.row_mut(i) {
                *x /= n;
            }
        }
        let v = Mat::randn(t, dv, 1.0, rng);
        let alpha: Vec<f32> = (0..t).map(|_| rng.range_f32(0.75, 1.0)).collect();
        let beta: Vec<f32> = (0..t).map(|_| rng.range_f32(0.1, 1.0)).collect();
        let nl = crate::fenwick::num_levels(t);
        let lambda = Mat::rand_uniform(t, nl, 0.05, 1.0, rng);
        AttnInputs { q, k, v, alpha, beta, lambda }
    }

    pub fn seq_len(&self) -> usize {
        self.q.rows
    }
}

/// Which architecture (Table 1 row).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Model {
    Softmax,
    Linear,
    Mamba2,
    DeltaNet,
    GatedDeltaNet,
    LogLinearMamba2,
    LogLinearGdn,
}

impl Model {
    pub fn name(&self) -> &'static str {
        match self {
            Model::Softmax => "softmax",
            Model::Linear => "linear",
            Model::Mamba2 => "mamba2",
            Model::DeltaNet => "deltanet",
            Model::GatedDeltaNet => "gated_deltanet",
            Model::LogLinearMamba2 => "loglinear_mamba2",
            Model::LogLinearGdn => "loglinear_gdn",
        }
    }

    pub fn from_name(s: &str) -> Option<Model> {
        Some(match s {
            "softmax" | "transformer" => Model::Softmax,
            "linear" => Model::Linear,
            "mamba2" => Model::Mamba2,
            "deltanet" => Model::DeltaNet,
            "gated_deltanet" | "gdn" => Model::GatedDeltaNet,
            "loglinear_mamba2" | "ll_mamba2" => Model::LogLinearMamba2,
            "loglinear_gdn" | "ll_gdn" => Model::LogLinearGdn,
            _ => return None,
        })
    }

    pub fn all() -> &'static [Model] {
        &[
            Model::Softmax,
            Model::Linear,
            Model::Mamba2,
            Model::DeltaNet,
            Model::GatedDeltaNet,
            Model::LogLinearMamba2,
            Model::LogLinearGdn,
        ]
    }

    pub fn is_loglinear(&self) -> bool {
        matches!(self, Model::LogLinearMamba2 | Model::LogLinearGdn)
    }
}

/// Which algorithmic form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Form {
    Recurrent,
    Parallel,
    /// Chunkwise with the given chunk size.
    Chunkwise(usize),
}

/// Unified dispatch used by benches and the eval harness. Softmax ignores
/// `Form` (always the standard parallel algorithm).
pub fn forward(model: Model, form: Form, x: &AttnInputs) -> Mat {
    match (model, form) {
        (Model::Softmax, _) => softmax::softmax_attention(&x.q, &x.k, &x.v),
        (Model::Linear, Form::Recurrent) => linear::recurrent(&x.q, &x.k, &x.v),
        (Model::Linear, Form::Parallel) => linear::parallel(&x.q, &x.k, &x.v),
        (Model::Linear, Form::Chunkwise(c)) => linear::chunkwise(&x.q, &x.k, &x.v, c),
        (Model::Mamba2, Form::Recurrent) => mamba2::recurrent(&x.q, &x.k, &x.v, &x.alpha),
        (Model::Mamba2, Form::Parallel) => mamba2::parallel(&x.q, &x.k, &x.v, &x.alpha),
        (Model::Mamba2, Form::Chunkwise(c)) => mamba2::chunkwise(&x.q, &x.k, &x.v, &x.alpha, c),
        (Model::DeltaNet, Form::Recurrent) => deltanet::recurrent(&x.q, &x.k, &x.v, &x.beta),
        (Model::DeltaNet, Form::Parallel) => deltanet::parallel(&x.q, &x.k, &x.v, &x.beta),
        (Model::DeltaNet, Form::Chunkwise(c)) => deltanet::chunkwise(&x.q, &x.k, &x.v, &x.beta, c),
        (Model::GatedDeltaNet, Form::Recurrent) => {
            gated_deltanet::recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta)
        }
        (Model::GatedDeltaNet, Form::Parallel) => {
            gated_deltanet::parallel(&x.q, &x.k, &x.v, &x.alpha, &x.beta)
        }
        (Model::GatedDeltaNet, Form::Chunkwise(c)) => {
            gated_deltanet::chunkwise(&x.q, &x.k, &x.v, &x.alpha, &x.beta, c)
        }
        (Model::LogLinearMamba2, Form::Recurrent) => {
            loglinear_mamba2::recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.lambda)
        }
        (Model::LogLinearMamba2, Form::Parallel) => {
            loglinear_mamba2::parallel(&x.q, &x.k, &x.v, &x.alpha, &x.lambda)
        }
        (Model::LogLinearMamba2, Form::Chunkwise(c)) => {
            loglinear_mamba2::chunkwise(&x.q, &x.k, &x.v, &x.alpha, &x.lambda, c)
        }
        (Model::LogLinearGdn, Form::Recurrent) => {
            loglinear_gdn::recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda)
        }
        (Model::LogLinearGdn, Form::Parallel) => {
            loglinear_gdn::parallel(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda)
        }
        (Model::LogLinearGdn, Form::Chunkwise(c)) => {
            loglinear_gdn::chunkwise(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda, c)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::assert_close;

    /// The headline equivalence suite: for every model, every form agrees
    /// with the recurrent oracle across several (T, C) combinations,
    /// including non-power-of-two T and chunk sizes that don't divide T.
    #[test]
    fn all_forms_agree_with_recurrent_oracle() {
        let mut rng = Rng::new(0xA77);
        for &model in Model::all() {
            if model == Model::Softmax {
                continue;
            }
            for &(t, c) in &[(8usize, 4usize), (32, 8), (48, 8), (64, 16), (100, 16), (128, 32)] {
                let x = AttnInputs::random(t, 12, 10, &mut rng);
                let oracle = forward(model, Form::Recurrent, &x);
                let par = forward(model, Form::Parallel, &x);
                if let Err(e) = crate::tensor::allclose(&par, &oracle, 2e-3, 2e-3) {
                    panic!("{} parallel != recurrent (T={t}): {e}", model.name());
                }
                let ck = forward(model, Form::Chunkwise(c), &x);
                if let Err(e) = crate::tensor::allclose(&ck, &oracle, 2e-3, 2e-3) {
                    panic!("{} chunkwise(C={c}) != recurrent (T={t}): {e}", model.name());
                }
            }
        }
    }

    /// Log-linear models collapse to their linear counterparts when all
    /// λ_t^(ℓ) = 1 (paper §3.1).
    #[test]
    fn loglinear_collapses_to_linear_variant() {
        let mut rng = Rng::new(0xB0B);
        for &t in &[32usize, 64, 96] {
            let mut x = AttnInputs::random(t, 8, 8, &mut rng);
            x.lambda = Mat::from_fn(t, crate::fenwick::num_levels(t), |_, _| 1.0);
            let llm = forward(Model::LogLinearMamba2, Form::Recurrent, &x);
            let m2 = forward(Model::Mamba2, Form::Recurrent, &x);
            assert_close(&llm, &m2, 1e-4, 1e-4);
            let llg = forward(Model::LogLinearGdn, Form::Recurrent, &x);
            let gdn = forward(Model::GatedDeltaNet, Form::Recurrent, &x);
            assert_close(&llg, &gdn, 1e-4, 1e-4);
        }
    }

    /// Mamba-2 with all gates = 1 is plain linear attention; DeltaNet with
    /// β = 0 writes nothing.
    #[test]
    fn degenerate_parameter_relations() {
        let mut rng = Rng::new(0xC4B);
        let t = 40;
        let mut x = AttnInputs::random(t, 8, 8, &mut rng);
        x.alpha = vec![1.0; t];
        let m2 = forward(Model::Mamba2, Form::Recurrent, &x);
        let lin = forward(Model::Linear, Form::Recurrent, &x);
        assert_close(&m2, &lin, 1e-5, 1e-5);

        let mut x2 = AttnInputs::random(t, 8, 8, &mut rng);
        x2.beta = vec![0.0; t];
        let dn = forward(Model::DeltaNet, Form::Recurrent, &x2);
        assert!(dn.fro_norm() < 1e-6);
    }

    #[test]
    fn chunk_size_one_and_full_sequence_chunks() {
        // Degenerate chunk sizes must still be correct: C=1 (pure
        // inter-chunk) and C=T (pure intra-chunk).
        let mut rng = Rng::new(0xD11);
        let t = 32;
        let x = AttnInputs::random(t, 8, 8, &mut rng);
        for &model in &[Model::Mamba2, Model::LogLinearMamba2, Model::GatedDeltaNet, Model::LogLinearGdn] {
            let oracle = forward(model, Form::Recurrent, &x);
            for &c in &[1usize, t] {
                let y = forward(model, Form::Chunkwise(c), &x);
                if let Err(e) = crate::tensor::allclose(&y, &oracle, 2e-3, 2e-3) {
                    panic!("{} C={c}: {e}", model.name());
                }
            }
        }
    }
}
