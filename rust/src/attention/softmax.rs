//! Standard causal softmax attention — the quadratic-compute,
//! linear-memory baseline (Table 1 row 1). Also provides the KV-cache
//! decoder used by the decode-complexity benches: `O(t)` work and memory
//! per step, versus the log-linear models' `O(log t)`.

use crate::tensor::{ops, Mat};

/// `O = softmax(Q K^T / sqrt(d) ⊙ causal) V`.
pub fn softmax_attention(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    let t = q.rows;
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut scores = q.matmul_nt(k);
    for i in 0..t {
        let row = scores.row_mut(i);
        for (j, s) in row.iter_mut().enumerate() {
            if j > i {
                *s = f32::NEG_INFINITY;
            } else {
                *s *= scale;
            }
        }
    }
    ops::softmax_rows(&mut scores);
    // masked upper triangle softmaxes to exact zeros — sparse path applies
    scores.matmul_sparse_rows(v)
}

/// Incremental KV-cache decoder: append one (k, v), produce the output for
/// the new query. Memory grows linearly with steps — the baseline the
/// paper's `O(log T)` decoding is compared against.
pub struct KvCacheDecoder {
    pub keys: Vec<Vec<f32>>,
    pub values: Vec<Vec<f32>>,
    scale: f32,
}

impl KvCacheDecoder {
    pub fn new(dk: usize) -> Self {
        KvCacheDecoder {
            keys: Vec::new(),
            values: Vec::new(),
            scale: 1.0 / (dk as f32).sqrt(),
        }
    }

    pub fn step(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Vec<f32> {
        self.keys.push(k.to_vec());
        self.values.push(v.to_vec());
        let mut scores: Vec<f32> = self
            .keys
            .iter()
            .map(|kk| crate::tensor::dot(q, kk) * self.scale)
            .collect();
        ops::softmax_inplace(&mut scores);
        let dv = v.len();
        let mut out = vec![0.0f32; dv];
        for (w, vv) in scores.iter().zip(self.values.iter()) {
            for (o, &x) in out.iter_mut().zip(vv.iter()) {
                *o += w * x;
            }
        }
        out
    }

    /// Bytes of cache state currently held (the decode-memory metric).
    pub fn state_bytes(&self) -> usize {
        let kb: usize = self.keys.iter().map(|k| k.len() * 4).sum();
        let vb: usize = self.values.iter().map(|v| v.len() * 4).sum();
        kb + vb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn rows_are_convex_combinations() {
        let mut rng = Rng::new(1);
        let x = crate::attention::AttnInputs::random(16, 8, 8, &mut rng);
        // With v >= 0, outputs stay within [min v, max v] per column.
        let mut v = x.v.clone();
        for val in v.data.iter_mut() {
            *val = val.abs();
        }
        let o = softmax_attention(&x.q, &x.k, &v);
        let vmax = v.data.iter().cloned().fold(0.0f32, f32::max);
        assert!(o.data.iter().all(|&y| y >= 0.0 && y <= vmax + 1e-5));
    }

    #[test]
    fn first_row_copies_v0() {
        let mut rng = Rng::new(2);
        let x = crate::attention::AttnInputs::random(8, 4, 4, &mut rng);
        let o = softmax_attention(&x.q, &x.k, &x.v);
        for j in 0..4 {
            assert!((o.at(0, j) - x.v.at(0, j)).abs() < 1e-6);
        }
    }

    #[test]
    fn kv_cache_decoder_matches_parallel() {
        let mut rng = Rng::new(3);
        let x = crate::attention::AttnInputs::random(24, 8, 8, &mut rng);
        let o_par = softmax_attention(&x.q, &x.k, &x.v);
        let mut dec = KvCacheDecoder::new(8);
        for t in 0..24 {
            let o = dec.step(x.q.row(t), x.k.row(t), x.v.row(t));
            for j in 0..8 {
                assert!(
                    (o[j] - o_par.at(t, j)).abs() < 1e-5,
                    "t={t} j={j}: {} vs {}",
                    o[j],
                    o_par.at(t, j)
                );
            }
        }
        // memory is linear in steps
        assert_eq!(dec.state_bytes(), 24 * (8 + 8) * 4);
    }

    #[test]
    fn causality_future_v_changes_nothing() {
        let mut rng = Rng::new(4);
        let x = crate::attention::AttnInputs::random(12, 6, 6, &mut rng);
        let o1 = softmax_attention(&x.q, &x.k, &x.v);
        let mut v2 = x.v.clone();
        for j in 0..6 {
            *v2.at_mut(11, j) = 999.0;
        }
        let o2 = softmax_attention(&x.q, &x.k, &v2);
        for t in 0..11 {
            for j in 0..6 {
                assert_eq!(o1.at(t, j), o2.at(t, j));
            }
        }
    }
}
