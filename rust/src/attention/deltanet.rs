//! DeltaNet (Schlag et al., 2021): linear attention whose state update is
//! the delta rule. Parallelized across sequence length via the WY/UT
//! representation of Householder products (Yang et al., 2024b) — the
//! `T_K(QK^T)` of the paper's Table 1.
//!
//! Recurrence (state `S: (d_k, d_v)`):
//! `S_t = (I − β_t k_t k_t^T) S_{t-1} + β_t k_t v_t^T`, `o_t = S_t^T q_t`.

use crate::tensor::{ops, Mat};

/// Recurrent oracle. Each step applies a Householder-like transition
/// `Φ_t = I − β_t k_t k_t^T` (rank-1 update, O(d_k d_v)).
pub fn recurrent(q: &Mat, k: &Mat, v: &Mat, beta: &[f32]) -> Mat {
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    assert_eq!(beta.len(), t);
    let mut s = Mat::zeros(dk, dv);
    let mut out = Mat::zeros(t, dv);
    for i in 0..t {
        apply_householder(&mut s, k.row(i), beta[i]);
        // S += β k v^T
        crate::tensor::outer_acc(&mut s, k.row(i), v.row(i), beta[i]);
        out.row_mut(i).copy_from_slice(&s.matvec_t(q.row(i)));
    }
    out
}

/// `S ← (I − β k k^T) S`, in place: `S -= β k (k^T S)`.
pub fn apply_householder(s: &mut Mat, k: &[f32], beta: f32) {
    apply_householder_slice(&mut s.data, s.cols, k, beta);
}

/// Slice form of [`apply_householder`] for row-major `(d_k, d_v)` states
/// that don't live in a [`Mat`] — e.g. the pooled decode blocks of
/// [`crate::state::pool::StatePool`]. Bit-identical to the `Mat` form
/// (same op order), so pooled and per-sequence decode agree exactly.
pub fn apply_householder_slice(s: &mut [f32], dv: usize, k: &[f32], beta: f32) {
    if beta == 0.0 {
        return;
    }
    debug_assert_eq!(s.len(), k.len() * dv);
    // kt_s = S^T k, accumulated row-wise like Mat::matvec_t
    let mut kt_s = vec![0.0f32; dv];
    crate::tensor::matvec_t_acc_slice(s, dv, k, 1.0, &mut kt_s);
    for (i, &ki) in k.iter().enumerate() {
        let scale = beta * ki;
        if scale == 0.0 {
            continue;
        }
        let row = &mut s[i * dv..(i + 1) * dv];
        for (r, &x) in row.iter_mut().zip(kt_s.iter()) {
            *r -= scale * x;
        }
    }
}

/// `x ← (I − β k k^T) x` for a vector (used for effective-query chains).
pub fn apply_householder_vec(x: &mut [f32], k: &[f32], beta: f32) {
    if beta == 0.0 {
        return;
    }
    let d = crate::tensor::dot(k, x) * beta;
    for (xi, &ki) in x.iter_mut().zip(k.iter()) {
        *xi -= d * ki;
    }
}

/// The UT-transform system matrix `B = I + StrictTril(diag(β) K K^T)`.
fn ut_system(k: &Mat, beta: &[f32]) -> Mat {
    let t = k.rows;
    let mut b = Mat::zeros(t, t);
    for i in 0..t {
        *b.at_mut(i, i) = 1.0;
        for j in 0..i {
            *b.at_mut(i, j) = beta[i] * crate::tensor::dot(k.row(i), k.row(j));
        }
    }
    b
}

/// Parallel (WY) form: solve `(I + StrictTril(diag(β) K K^T)) W = diag(β) V`
/// for the pseudo-values `W`, then `O = tril(Q K^T) W`.
pub fn parallel(q: &Mat, k: &Mat, v: &Mat, beta: &[f32]) -> Mat {
    let t = q.rows;
    let b = ut_system(k, beta);
    let mut rhs = v.clone();
    for i in 0..t {
        for x in rhs.row_mut(i) {
            *x *= beta[i];
        }
    }
    let w = ops::solve_unit_lower(&b, &rhs);
    let mut qk = q.matmul_nt(k);
    for i in 0..t {
        for j in i + 1..t {
            *qk.at_mut(i, j) = 0.0;
        }
    }
    qk.matmul_sparse_rows(&w)
}

/// The explicit DeltaNet attention matrix
/// `A^δ = tril(Q K^T) (I + StrictTril(diag(β) K K^T))^{-1} diag(β)`
/// (the paper's `T_K(QK^T)`). Needed when a mask must be applied
/// *elementwise* on top (Gated DeltaNet's `M^S`, log-linear's `M^H`).
pub fn attn_matrix(q: &Mat, k: &Mat, beta: &[f32]) -> Mat {
    let t = q.rows;
    let b = ut_system(k, beta);
    let mut qk = q.matmul_nt(k);
    for i in 0..t {
        for j in i + 1..t {
            *qk.at_mut(i, j) = 0.0;
        }
    }
    // A = qk B^{-1} diag(β)  =>  B^T (diag(1/β) A^T)' ... solve on transposes:
    // B^T Y = qk^T, then A[t][s] = β_s Y[s][t].
    let y = ops::solve_unit_upper(&b.transpose(), &qk.transpose());
    Mat::from_fn(t, t, |ti, si| beta[si] * y.at(si, ti))
}

/// Chunkwise form: the gated chunk primitive with all gates = 1.
pub fn chunkwise(q: &Mat, k: &Mat, v: &Mat, beta: &[f32], c: usize) -> Mat {
    let alpha = vec![1.0f32; q.rows];
    super::gated_deltanet::chunkwise(q, k, v, &alpha, beta, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn parallel_equals_recurrent() {
        let mut rng = Rng::new(1);
        for &t in &[1usize, 2, 9, 32, 64] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &parallel(&x.q, &x.k, &x.v, &x.beta),
                &recurrent(&x.q, &x.k, &x.v, &x.beta),
                1e-3,
                1e-3,
            );
        }
    }

    #[test]
    fn attn_matrix_reproduces_parallel() {
        let mut rng = Rng::new(2);
        let x = AttnInputs::random(24, 8, 6, &mut rng);
        let a = attn_matrix(&x.q, &x.k, &x.beta);
        assert_close(
            &a.matmul(&x.v),
            &parallel(&x.q, &x.k, &x.v, &x.beta),
            1e-3,
            1e-3,
        );
        // A is lower-triangular.
        for i in 0..24 {
            for j in i + 1..24 {
                assert_eq!(a.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn beta_one_normalized_keys_erase_then_write() {
        // With β=1 and unit keys, writing (k, v) then querying with q = k
        // returns exactly v (the delta rule replaces the stored value).
        let dk = 4;
        let mut k = Mat::zeros(2, dk);
        *k.at_mut(0, 0) = 1.0;
        *k.at_mut(1, 0) = 1.0; // same key twice
        let mut v = Mat::zeros(2, 2);
        *v.at_mut(0, 0) = 5.0;
        *v.at_mut(1, 1) = 7.0; // overwrite with different value
        let q = k.clone();
        let o = recurrent(&q, &k, &v, &[1.0, 1.0]);
        // At t=1 the state for key k must hold v_1, not v_0 + v_1.
        assert!((o.at(1, 0) - 0.0).abs() < 1e-5);
        assert!((o.at(1, 1) - 7.0).abs() < 1e-5);
    }

    #[test]
    fn householder_is_contraction_for_unit_keys() {
        let mut rng = Rng::new(3);
        let mut s = Mat::randn(8, 8, 1.0, &mut rng);
        let norm0 = s.fro_norm();
        let mut k: Vec<f32> = (0..8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let n = crate::tensor::ops::l2_norm(&k);
        for x in k.iter_mut() {
            *x /= n;
        }
        apply_householder(&mut s, &k, 0.7);
        assert!(s.fro_norm() <= norm0 * (1.0 + 1e-5));
    }

    #[test]
    fn vec_and_mat_householder_agree() {
        let mut rng = Rng::new(4);
        let k: Vec<f32> = (0..6).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut s = Mat::randn(6, 1, 1.0, &mut rng);
        let mut x: Vec<f32> = (0..6).map(|i| s.at(i, 0)).collect();
        apply_householder(&mut s, &k, 0.5);
        apply_householder_vec(&mut x, &k, 0.5);
        for i in 0..6 {
            assert!((s.at(i, 0) - x[i]).abs() < 1e-6);
        }
    }
}
