//! Shared machinery for log-linear attention (paper §3):
//!
//! - [`parallel_from_a`]: the generic parallel form
//!   `O = (A ⊙ M^S ⊙ M^H) V` for any interaction matrix `A` (Eq. 4 / §3.4)
//!   — `M^S ⊙ M^H` *is* [`crate::hmatrix::QuasiH`].
//! - [`ChunkFenwick`]: the chunk-granularity Fenwick state engine at the
//!   heart of the chunkwise training algorithm (Alg. 1). It is the §3.2
//!   recurrence lifted from tokens to chunks: before chunk `z`, buckets
//!   `0..=lssb(z)` merge one level up; after chunk `z`, all live states
//!   pass through the chunk's transition and the fresh chunk state enters
//!   at level 0. Inter-chunk levels map to token levels as
//!   `token_level = log2(C) + chunk_level`.
//!
//! The engine is built for the matmul-rich form of §3.5: instead of one
//! `S^T q` matvec per (token, level), [`ChunkFenwick::read_levels_into`]
//! concatenates the `O(log T)` live states into a single `(d_k, L·d_v)`
//! matrix and reads a whole chunk of queries against it with **one GEMM**,
//! folding the per-level λ weights afterwards. It is also allocation-free
//! in steady state: merged-out states go to an internal free list that
//! [`ChunkFenwick::take_buffer`] recycles, and the concat/read workspaces
//! persist across chunks (and across sequences via
//! [`ChunkFenwick::reset`]).
//!
//! Both log-linear instantiations (Mamba-2 and Gated DeltaNet) drive this
//! engine with their own transitions (scalar decay vs. gated Householder
//! chain), which is exactly the paper's claim that any linear-attention
//! model with an efficient chunkwise primitive can be "lifted".
//!
//! Serving-side consumers: the prompt-prefill subsystem
//! ([`crate::prefill`]) runs a head-batched, state-only variant of this
//! hierarchy ([`crate::prefill::PrefillEngine`]) and exports it — or a
//! plain [`ChunkFenwick`] — into pool-backed decode states through
//! [`crate::prefill::bridge`] at any chunk boundary (the level layouts
//! coincide at the token machine's post-merge boundary; see the bridge
//! docs for the alignment argument).

use crate::fenwick;
use crate::hmatrix::QuasiH;
use crate::tensor::{self, Mat};

/// Generic parallel form: `O = (A ⊙ M^S ⊙ M^H) V`.
///
/// `a` must be the model's (lower-triangular) interaction matrix:
/// `Q K^T` for Mamba-2, `T_K(Q K^T)` for Gated DeltaNet.
pub fn parallel_from_a(a: &Mat, alpha: &[f32], lambda: &Mat, v: &Mat) -> Mat {
    let quasi = QuasiH::new(alpha, lambda).dense();
    // the masked product is lower-triangular: ~half structural zeros
    a.hadamard(&quasi).matmul_sparse_rows(v)
}

/// Chunk-granularity Fenwick state set. `levels[m]` holds the bucket state
/// for chunk-level `m >= 1` (a `(d_k, d_v)` matrix summarizing
/// `2^(m-1)` chunks); `level0` holds the most recent chunk's state.
///
/// Owns its workspaces (state free list, concat buffer, GEMM read buffer)
/// so a chunkwise sweep allocates nothing per chunk after warm-up.
#[derive(Debug, Clone, Default)]
pub struct ChunkFenwick {
    level0: Option<Mat>,
    levels: Vec<Option<Mat>>,
    /// state shape, fixed on first write (0 until then)
    dk: usize,
    dv: usize,
    /// recycled (dk, dv) buffers from merged-out states
    free: Vec<Mat>,
    /// concat workspace: row-major (dk, live_levels * dv)
    cat: Vec<f32>,
    /// GEMM output workspace: (chunk_len, live_levels * dv)
    read_buf: Vec<f32>,
    /// chunk-levels (>= 1) live at the last concat, panel order
    active_ids: Vec<usize>,
}

impl ChunkFenwick {
    pub fn new() -> ChunkFenwick {
        ChunkFenwick::default()
    }

    /// Merge step before processing chunk `z` (no-op for `z = 0`):
    /// levels `0..=lssb(z)` sum into level `lssb(z)+1`. Merged-out
    /// buffers are recycled, not dropped.
    pub fn advance(&mut self, z: usize) {
        if z == 0 {
            return;
        }
        let l = fenwick::lssb(z) as usize;
        let mut merged: Option<Mat> = self.level0.take();
        for m in 1..=l {
            if let Some(s) = self.levels.get_mut(m - 1).and_then(|x| x.take()) {
                match merged {
                    None => merged = Some(s),
                    Some(ref mut acc) => {
                        acc.axpy(1.0, &s);
                        self.free.push(s);
                    }
                }
            }
        }
        if let Some(s) = merged {
            let idx = l; // levels[idx] = chunk-level idx+1 = lssb+1
            if self.levels.len() <= idx {
                self.levels.resize(idx + 1, None);
            }
            debug_assert!(self.levels[idx].is_none(), "Fenwick invariant violated");
            self.levels[idx] = Some(s);
        }
    }

    /// Active (chunk_level >= 1, state) pairs for the current query chunk.
    pub fn active(&self) -> impl Iterator<Item = (usize, &Mat)> {
        self.levels
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|m| (i + 1, m)))
    }

    /// Number of live states (≈ popcount of the chunk index, App. B.4).
    pub fn live_states(&self) -> usize {
        self.levels.iter().filter(|s| s.is_some()).count() + usize::from(self.level0.is_some())
    }

    /// Whether a chunk-sentinel (level-0) state is currently installed —
    /// false right after [`ChunkFenwick::advance`] merged it away, which
    /// is the boundary the prefill export bridge
    /// (`crate::prefill::bridge`) requires.
    pub fn has_level0(&self) -> bool {
        self.level0.is_some()
    }

    /// State shape `(d_k, d_v)`, or `(0, 0)` before the first write.
    pub fn state_dims(&self) -> (usize, usize) {
        (self.dk, self.dv)
    }

    /// Apply the current chunk's transition to every live state.
    pub fn apply_transition(&mut self, mut f: impl FnMut(&mut Mat)) {
        if let Some(s) = self.level0.as_mut() {
            f(s);
        }
        for s in self.levels.iter_mut().flatten() {
            f(s);
        }
    }

    /// Apply a matrix transition `S ← Φ S` to every live state as dense
    /// GEMMs (`Φ` is `(d_k, d_k)`, e.g. a chunk's Householder-chain
    /// product). Uses a recycled scratch buffer — no allocation in steady
    /// state.
    pub fn apply_matrix_transition(&mut self, phi: &Mat) {
        if self.dk == 0 {
            return;
        }
        assert_eq!((phi.rows, phi.cols), (self.dk, self.dk), "transition shape");
        let mut tmp = match self.free.pop() {
            Some(m) => m,
            None => Mat::zeros(self.dk, self.dv),
        };
        if let Some(s) = self.level0.as_mut() {
            phi.matmul_into(s, &mut tmp);
            std::mem::swap(&mut s.data, &mut tmp.data);
        }
        for s in self.levels.iter_mut().flatten() {
            phi.matmul_into(s, &mut tmp);
            std::mem::swap(&mut s.data, &mut tmp.data);
        }
        self.free.push(tmp);
    }

    /// A zeroed `(dk, dv)` buffer for the next chunk state, recycled from
    /// the free list when possible. Fill it (e.g. via
    /// [`crate::tensor::gemm_tn_diag_acc`]) and install it with
    /// [`ChunkFenwick::set_level0`].
    pub fn take_buffer(&mut self, dk: usize, dv: usize) -> Mat {
        if self.dk == 0 {
            self.dk = dk;
            self.dv = dv;
        }
        assert_eq!((self.dk, self.dv), (dk, dv), "state shape changed mid-sequence");
        match self.free.pop() {
            Some(mut m) => {
                m.data.fill(0.0);
                m
            }
            None => Mat::zeros(dk, dv),
        }
    }

    /// Install the freshly-computed chunk state at level 0.
    pub fn set_level0(&mut self, s: Mat) {
        debug_assert!(self.level0.is_none(), "level0 must be merged before rewrite");
        if self.dk == 0 {
            self.dk = s.rows;
            self.dv = s.cols;
        }
        self.level0 = Some(s);
    }

    /// Install a bucket state directly at chunk level `m >= 1` — the
    /// boundary-seeding inverse of [`ChunkFenwick::active`], used to
    /// resume a chunkwise sweep from states exported at an earlier
    /// boundary (prefix-cache hits). The caller is responsible for
    /// Fenwick alignment against the chunk index it will resume at (the
    /// prefill engine's seeded constructor validates it).
    pub fn install_level(&mut self, m: usize, s: Mat) {
        assert!(m >= 1, "level 0 is the chunk sentinel; use set_level0");
        if self.dk == 0 {
            self.dk = s.rows;
            self.dv = s.cols;
        }
        assert_eq!((s.rows, s.cols), (self.dk, self.dv), "state shape");
        if self.levels.len() < m {
            self.levels.resize(m, None);
        }
        assert!(self.levels[m - 1].is_none(), "level {m} already live");
        self.levels[m - 1] = Some(s);
    }

    /// Clear all states for a new sequence, keeping the recycled buffers
    /// and workspaces (zero-alloc reuse across sequences).
    pub fn reset(&mut self) {
        if let Some(s) = self.level0.take() {
            self.free.push(s);
        }
        for slot in self.levels.iter_mut() {
            if let Some(s) = slot.take() {
                self.free.push(s);
            }
        }
    }

    /// Batched inter-chunk level read (§3.5's level fusion as one GEMM):
    /// concatenates the live level states into `S_cat: (d_k, L·d_v)`,
    /// computes `P = Q_block @ S_cat` in a single GEMM, then folds level
    /// panels into `out` rows `out_row0..out_row0+len` with
    /// `out[out_row0+i] += weight(i, level) · P[i, panel(level)]`.
    ///
    /// `q_block` is row-major `(len, d_k)` — pass a zero-copy
    /// [`Mat::rows_data`] view of Q (or of the effective queries for
    /// delta-rule models). `weight` receives the chunk-local row index and
    /// the chunk-level `m >= 1`; return 0 to skip a (row, level) pair.
    pub fn read_levels_into(
        &mut self,
        q_block: &[f32],
        len: usize,
        out: &mut Mat,
        out_row0: usize,
        mut weight: impl FnMut(usize, usize) -> f32,
    ) {
        let (dk, dv) = (self.dk, self.dv);
        if dk == 0 || len == 0 {
            return;
        }
        assert_eq!(q_block.len(), len * dk, "q_block shape");
        assert!(out_row0 + len <= out.rows && out.cols == dv, "out shape");
        // 1) gather live levels (chunk_level >= 1), panel order = level order
        self.active_ids.clear();
        for (i, s) in self.levels.iter().enumerate() {
            if s.is_some() {
                self.active_ids.push(i + 1);
            }
        }
        let nl = self.active_ids.len();
        if nl == 0 {
            return;
        }
        let ncat = nl * dv;
        // 2) concat: row r of S_cat is [S^(m1) row r | S^(m2) row r | ...]
        self.cat.clear();
        self.cat.resize(dk * ncat, 0.0);
        for (li, &lvl) in self.active_ids.iter().enumerate() {
            let s = self.levels[lvl - 1].as_ref().expect("active level live");
            for r in 0..dk {
                let dst = r * ncat + li * dv;
                self.cat[dst..dst + dv].copy_from_slice(s.row(r));
            }
        }
        // 3) one GEMM for the whole chunk's level reads
        self.read_buf.clear();
        self.read_buf.resize(len * ncat, 0.0);
        tensor::gemm_into(len, dk, ncat, q_block, &self.cat, &mut self.read_buf, false);
        // 4) λ-weighted level fold
        for i in 0..len {
            let prow = &self.read_buf[i * ncat..(i + 1) * ncat];
            let orow = out.row_mut(out_row0 + i);
            for (li, &lvl) in self.active_ids.iter().enumerate() {
                let w = weight(i, lvl);
                if w == 0.0 {
                    continue;
                }
                tensor::axpy8(orow, &prow[li * dv..(li + 1) * dv], w);
            }
        }
    }
}

/// Decode-time λ-weighted level read: `out += λ · S^T q` for one
/// row-major `(d_k, d_v)` level state `s`. This is the shared read-path
/// primitive of the serving stack — both the per-sequence
/// [`crate::state::FenwickState`] and the pooled batched decoder
/// ([`crate::state::pooled::BatchedDecoder`]) reduce to exactly this op
/// sequence per (sequence, level), so the two paths are bit-identical by
/// construction.
#[inline]
pub fn level_read_acc(s: &[f32], dv: usize, q: &[f32], lam: f32, out: &mut [f32]) {
    tensor::matvec_t_acc_slice(s, dv, q, lam, out);
}

/// Intra-chunk λ mask: `Λ[i][j] = lambda[start+i][level_of(i, j)]` for
/// `j <= i` within a chunk (chunk-local offsets equal absolute levels for
/// intra-chunk pairs — see `fenwick::tests::intra_chunk_levels_are_local`).
pub fn local_lambda_mask(lambda: &Mat, start: usize, len: usize) -> Mat {
    Mat::from_fn(len, len, |i, j| {
        if j > i {
            0.0
        } else {
            lambda.at(start + i, fenwick::level_of(i, j))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn engine_replays_fenwick_bucket_sums() {
        // Drive the engine with identity transitions and rank-1 "states"
        // holding one-hot chunk markers; after advance(z) the active
        // buckets must match fenwick::buckets(z) exactly.
        let zmax = 64;
        let mut eng = ChunkFenwick::new();
        for z in 0..zmax {
            eng.advance(z);
            let bs = crate::fenwick::buckets(z);
            // every active level-(m>=1) state sums the chunk markers of
            // its bucket
            for (m, s) in eng.active() {
                let b = bs
                    .iter()
                    .find(|b| b.level == m)
                    .unwrap_or_else(|| panic!("z={z}: engine level {m} has no bucket"));
                // state = sum of one-hots of chunks in bucket
                for c in 0..zmax {
                    let expect = if b.contains(c) { 1.0 } else { 0.0 };
                    assert_eq!(s.at(0, c), expect, "z={z} level={m} chunk={c}");
                }
            }
            // count matches active bucket count (minus sentinel)
            let nonzero_buckets = bs.len() - 1;
            assert_eq!(
                eng.active().count(),
                nonzero_buckets,
                "z={z}"
            );
            // write marker for chunk z
            let mut m = eng.take_buffer(1, zmax);
            *m.at_mut(0, z) = 1.0;
            eng.set_level0(m);
        }
    }

    #[test]
    fn transitions_touch_all_live_states() {
        let mut eng = ChunkFenwick::new();
        for z in 0..8 {
            eng.advance(z);
            eng.apply_transition(|s| s.scale_inplace(2.0));
            eng.set_level0(Mat::from_vec(1, 1, vec![1.0]));
        }
        // After 8 chunks: states hold sums of powers of two — just check
        // total equals sum over chunks of 2^(age) where age = 7 - z.
        eng.advance(8);
        let total: f32 = eng.active().map(|(_, s)| s.at(0, 0)).sum();
        let expect: f32 = (0..8).map(|z| 2.0f32.powi(7 - z)).sum();
        assert!((total - expect).abs() < 1e-4);
    }

    #[test]
    fn matrix_transition_matches_scalar_for_diagonal_phi() {
        // Φ = c·I must agree with scale_inplace(c) on every live state.
        let mut rng = Rng::new(7);
        let (dk, dv) = (6, 5);
        let mut a = ChunkFenwick::new();
        let mut b = ChunkFenwick::new();
        for z in 0..13 {
            a.advance(z);
            b.advance(z);
            a.apply_transition(|s| s.scale_inplace(0.9));
            b.apply_matrix_transition(&Mat::eye(dk).scale(0.9));
            let w = Mat::randn(dk, dv, 1.0, &mut rng);
            a.set_level0(w.clone());
            b.set_level0(w);
        }
        a.advance(13);
        b.advance(13);
        let sa: Vec<&Mat> = a.active().map(|(_, s)| s).collect();
        let sb: Vec<&Mat> = b.active().map(|(_, s)| s).collect();
        assert_eq!(sa.len(), sb.len());
        for (x, y) in sa.iter().zip(sb.iter()) {
            crate::tensor::assert_close(x, y, 1e-4, 1e-4);
        }
    }

    #[test]
    fn batched_read_matches_per_level_matvecs() {
        // read_levels_into (one GEMM + fold) against the scalar loop it
        // replaced: per active level, out_i += w * S^(m)T q_i.
        let mut rng = Rng::new(8);
        let (dk, dv, len) = (7, 6, 5);
        let mut eng = ChunkFenwick::new();
        for z in 0..11 {
            eng.advance(z);
            eng.set_level0(Mat::randn(dk, dv, 1.0, &mut rng));
        }
        eng.advance(11);
        let q = Mat::randn(len, dk, 1.0, &mut rng);
        let lam = Mat::rand_uniform(len, 8, 0.0, 1.0, &mut rng);

        let mut want = Mat::zeros(len, dv);
        for i in 0..len {
            for (m, s) in eng.active() {
                let w = lam.at(i, m);
                s.matvec_t_acc(q.row(i), w, want.row_mut(i));
            }
        }
        let mut got = Mat::zeros(len, dv);
        eng.read_levels_into(q.rows_data(0, len), len, &mut got, 0, |i, m| lam.at(i, m));
        crate::tensor::assert_close(&got, &want, 1e-4, 1e-4);
    }

    #[test]
    fn workspace_reuse_across_two_sequences() {
        // A reset engine re-driven on fresh data must agree with a fresh
        // engine, and recycle its buffers instead of allocating.
        let mut rng = Rng::new(9);
        let (dk, dv, len) = (6, 4, 4);
        let drive = |eng: &mut ChunkFenwick, states: &[Mat], q: &Mat| -> Mat {
            let mut out = Mat::zeros(len, dv);
            for (z, w) in states.iter().enumerate() {
                eng.advance(z);
                eng.apply_transition(|s| s.scale_inplace(0.95));
                let mut buf = eng.take_buffer(dk, dv);
                buf.data.copy_from_slice(&w.data);
                eng.set_level0(buf);
            }
            eng.advance(states.len());
            eng.read_levels_into(q.rows_data(0, len), len, &mut out, 0, |_, _| 1.0);
            out
        };
        let seq_a: Vec<Mat> = (0..9).map(|_| Mat::randn(dk, dv, 1.0, &mut rng)).collect();
        let seq_b: Vec<Mat> = (0..6).map(|_| Mat::randn(dk, dv, 1.0, &mut rng)).collect();
        let q = Mat::randn(len, dk, 1.0, &mut rng);

        let mut reused = ChunkFenwick::new();
        let a1 = drive(&mut reused, &seq_a, &q);
        reused.reset();
        let b1 = drive(&mut reused, &seq_b, &q);

        let a2 = drive(&mut ChunkFenwick::new(), &seq_a, &q);
        let b2 = drive(&mut ChunkFenwick::new(), &seq_b, &q);
        crate::tensor::assert_close(&a1, &a2, 1e-5, 1e-5);
        crate::tensor::assert_close(&b1, &b2, 1e-5, 1e-5);
        // reset recycles every live state onto the free list
        reused.reset();
        assert_eq!(reused.live_states(), 0);
        assert!(!reused.free.is_empty(), "reset must keep buffers for reuse");
    }

    #[test]
    fn local_lambda_mask_levels() {
        let mut rng = Rng::new(1);
        let lambda = Mat::rand_uniform(32, 6, 0.0, 1.0, &mut rng);
        let m = local_lambda_mask(&lambda, 16, 8);
        for i in 0..8 {
            for j in 0..8 {
                if j > i {
                    assert_eq!(m.at(i, j), 0.0);
                } else {
                    assert_eq!(m.at(i, j), lambda.at(16 + i, crate::fenwick::level_of(i, j)));
                }
            }
        }
    }
}
