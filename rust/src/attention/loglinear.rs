//! Shared machinery for log-linear attention (paper §3):
//!
//! - [`parallel_from_a`]: the generic parallel form
//!   `O = (A ⊙ M^S ⊙ M^H) V` for any interaction matrix `A` (Eq. 4 / §3.4)
//!   — `M^S ⊙ M^H` *is* [`crate::hmatrix::QuasiH`].
//! - [`ChunkFenwick`]: the chunk-granularity Fenwick state engine at the
//!   heart of the chunkwise training algorithm (Alg. 1). It is the §3.2
//!   recurrence lifted from tokens to chunks: before chunk `z`, buckets
//!   `0..=lssb(z)` merge one level up; after chunk `z`, all live states
//!   pass through the chunk's transition and the fresh chunk state enters
//!   at level 0. Inter-chunk levels map to token levels as
//!   `token_level = log2(C) + chunk_level`.
//!
//! Both log-linear instantiations (Mamba-2 and Gated DeltaNet) drive this
//! engine with their own transitions (scalar decay vs. gated Householder
//! chain), which is exactly the paper's claim that any linear-attention
//! model with an efficient chunkwise primitive can be "lifted".

use crate::fenwick;
use crate::hmatrix::QuasiH;
use crate::tensor::Mat;

/// Generic parallel form: `O = (A ⊙ M^S ⊙ M^H) V`.
///
/// `a` must be the model's (lower-triangular) interaction matrix:
/// `Q K^T` for Mamba-2, `T_K(Q K^T)` for Gated DeltaNet.
pub fn parallel_from_a(a: &Mat, alpha: &[f32], lambda: &Mat, v: &Mat) -> Mat {
    let quasi = QuasiH::new(alpha.to_vec(), lambda.clone()).dense();
    a.hadamard(&quasi).matmul(v)
}

/// Chunk-granularity Fenwick state set. `levels[m]` holds the bucket state
/// for chunk-level `m >= 1` (a `(d_k, d_v)` matrix summarizing
/// `2^(m-1)` chunks); `level0` holds the most recent chunk's state.
#[derive(Debug, Clone)]
pub struct ChunkFenwick {
    level0: Option<Mat>,
    levels: Vec<Option<Mat>>,
}

impl Default for ChunkFenwick {
    fn default() -> Self {
        Self::new()
    }
}

impl ChunkFenwick {
    pub fn new() -> ChunkFenwick {
        ChunkFenwick { level0: None, levels: Vec::new() }
    }

    /// Merge step before processing chunk `z` (no-op for `z = 0`):
    /// levels `0..=lssb(z)` sum into level `lssb(z)+1`.
    pub fn advance(&mut self, z: usize) {
        if z == 0 {
            return;
        }
        let l = fenwick::lssb(z) as usize;
        let mut merged: Option<Mat> = self.level0.take();
        for m in 1..=l {
            if let Some(s) = self.levels.get_mut(m - 1).and_then(|x| x.take()) {
                match merged {
                    None => merged = Some(s),
                    Some(ref mut acc) => acc.axpy(1.0, &s),
                }
            }
        }
        if let Some(s) = merged {
            let idx = l; // levels[idx] = chunk-level idx+1 = lssb+1
            if self.levels.len() <= idx {
                self.levels.resize(idx + 1, None);
            }
            debug_assert!(self.levels[idx].is_none(), "Fenwick invariant violated");
            self.levels[idx] = Some(s);
        }
    }

    /// Active (chunk_level >= 1, state) pairs for the current query chunk.
    pub fn active(&self) -> impl Iterator<Item = (usize, &Mat)> {
        self.levels
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|m| (i + 1, m)))
    }

    /// Number of live states (≈ popcount of the chunk index, App. B.4).
    pub fn live_states(&self) -> usize {
        self.levels.iter().filter(|s| s.is_some()).count() + usize::from(self.level0.is_some())
    }

    /// Apply the current chunk's transition to every live state.
    pub fn apply_transition(&mut self, mut f: impl FnMut(&mut Mat)) {
        if let Some(s) = self.level0.as_mut() {
            f(s);
        }
        for s in self.levels.iter_mut().flatten() {
            f(s);
        }
    }

    /// Install the freshly-computed chunk state at level 0.
    pub fn set_level0(&mut self, s: Mat) {
        debug_assert!(self.level0.is_none(), "level0 must be merged before rewrite");
        self.level0 = Some(s);
    }
}

/// Intra-chunk λ mask: `Λ[i][j] = lambda[start+i][level_of(i, j)]` for
/// `j <= i` within a chunk (chunk-local offsets equal absolute levels for
/// intra-chunk pairs — see `fenwick::tests::intra_chunk_levels_are_local`).
pub fn local_lambda_mask(lambda: &Mat, start: usize, len: usize) -> Mat {
    Mat::from_fn(len, len, |i, j| {
        if j > i {
            0.0
        } else {
            lambda.at(start + i, fenwick::level_of(i, j))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn engine_replays_fenwick_bucket_sums() {
        // Drive the engine with identity transitions and rank-1 "states"
        // holding one-hot chunk markers; after advance(z) the active
        // buckets must match fenwick::buckets(z) exactly.
        let zmax = 64;
        let mut eng = ChunkFenwick::new();
        for z in 0..zmax {
            eng.advance(z);
            let bs = crate::fenwick::buckets(z);
            // every active level-(m>=1) state sums the chunk markers of
            // its bucket
            for (m, s) in eng.active() {
                let b = bs
                    .iter()
                    .find(|b| b.level == m)
                    .unwrap_or_else(|| panic!("z={z}: engine level {m} has no bucket"));
                // state = sum of one-hots of chunks in bucket
                for c in 0..zmax {
                    let expect = if b.contains(c) { 1.0 } else { 0.0 };
                    assert_eq!(s.at(0, c), expect, "z={z} level={m} chunk={c}");
                }
            }
            // count matches active bucket count (minus sentinel)
            let nonzero_buckets = bs.len() - 1;
            assert_eq!(
                eng.active().count(),
                nonzero_buckets,
                "z={z}"
            );
            // write marker for chunk z
            let mut m = Mat::zeros(1, zmax);
            *m.at_mut(0, z) = 1.0;
            eng.set_level0(m);
        }
    }

    #[test]
    fn transitions_touch_all_live_states() {
        let mut eng = ChunkFenwick::new();
        for z in 0..8 {
            eng.advance(z);
            eng.apply_transition(|s| s.scale_inplace(2.0));
            eng.set_level0(Mat::from_vec(1, 1, vec![1.0]));
        }
        // After 8 chunks: states hold sums of powers of two — just check
        // total equals sum over chunks of 2^(age) where age = 7 - z.
        eng.advance(8);
        let total: f32 = eng.active().map(|(_, s)| s.at(0, 0)).sum();
        let expect: f32 = (0..8).map(|z| 2.0f32.powi(7 - z)).sum();
        assert!((total - expect).abs() < 1e-4);
    }

    #[test]
    fn local_lambda_mask_levels() {
        let mut rng = Rng::new(1);
        let lambda = Mat::rand_uniform(32, 6, 0.0, 1.0, &mut rng);
        let m = local_lambda_mask(&lambda, 16, 8);
        for i in 0..8 {
            for j in 0..8 {
                if j > i {
                    assert_eq!(m.at(i, j), 0.0);
                } else {
                    assert_eq!(m.at(i, j), lambda.at(16 + i, crate::fenwick::level_of(i, j)));
                }
            }
        }
    }
}
