//! Gated DeltaNet (Yang et al., 2024a): the delta rule composed with a
//! data-dependent scalar decay gate.
//!
//! Recurrence: `S_t = α_t (I − β_t k_t k_t^T) S_{t-1} + β_t k_t v_t^T`.
//!
//! Because the gates are scalars they commute with the Householder chain,
//! so the parallel form is exactly the paper's
//! `O = (T_K(QK^T) ⊙ M^S) V`: the ungated DeltaNet attention matrix
//! masked elementwise by the 1-semiseparable gate mask.
//!
//! The chunkwise form uses the numerically-stable scaled UT transform
//! (all intermediate ratios `G_t/G_s ≤ 1` for `s < t`): per chunk,
//! solve `(I + StrictTril(M)) Ŵ = diag(β)(V − diag(G) K S_in)` with
//! `M[t][s] = β_t (k_t·k_s) G_t/G_s`, then
//! `O = diag(G) Q S_in + (tril(QK^T) ⊙ Gratio) Ŵ` and
//! `S_out = G_C S_in + Σ_s (G_C/G_s) k_s ŵ_s^T`.

use crate::hmatrix::sss::SssMask;
use crate::tensor::{self, ops, Mat};

use super::deltanet;

/// Recurrent oracle.
pub fn recurrent(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32]) -> Mat {
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    assert_eq!(alpha.len(), t);
    assert_eq!(beta.len(), t);
    let mut s = Mat::zeros(dk, dv);
    let mut out = Mat::zeros(t, dv);
    for i in 0..t {
        deltanet::apply_householder(&mut s, k.row(i), beta[i]);
        s.scale_inplace(alpha[i]);
        crate::tensor::outer_acc(&mut s, k.row(i), v.row(i), beta[i]);
        out.row_mut(i).copy_from_slice(&s.matvec_t(q.row(i)));
    }
    out
}

/// Parallel form: `O = (A^δ ⊙ M^S) V` with `A^δ` the DeltaNet attention
/// matrix — scalar gates factor out of the Householder product.
pub fn parallel(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32]) -> Mat {
    let a = deltanet::attn_matrix(q, k, beta);
    let p = a.hadamard(&SssMask::new(alpha).dense());
    p.matmul_sparse_rows(v)
}

/// Result of running one chunk: per-position outputs plus outgoing state.
pub struct ChunkOut {
    pub o: Mat,
    pub s_out: Mat,
}

/// The gated-delta chunk primitive (stable scaled UT transform).
///
/// Processes positions `[start, end)` given the state at chunk entry
/// (covering all transitions through `start-1`). Returns the chunk's
/// outputs and the state at chunk exit.
#[allow(clippy::too_many_arguments)]
pub fn gdn_chunk(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    alpha: &[f32],
    beta: &[f32],
    start: usize,
    end: usize,
    s_in: &Mat,
) -> ChunkOut {
    let len = end - start;
    let (dk, dv) = (k.cols, v.cols);
    // G[i] = Π_{j=start..start+i} α_j  (decay through position i, local).
    let mut g = vec![0.0f32; len];
    let mut acc = 1.0f64;
    for i in 0..len {
        acc *= alpha[start + i] as f64;
        g[i] = acc as f32;
    }

    // System matrix M (strict lower) with entries β_t (k_t·k_s) G_t/G_s:
    // one K_c K_c^T GEMM, then an O(len^2) scaling pass.
    let mut sys = Mat::zeros(len, len);
    tensor::gemm_nt_into(len, dk, len, k.rows_data(start, end), k.rows_data(start, end), &mut sys.data, false);
    for i in 0..len {
        let row = sys.row_mut(i);
        for (j, sij) in row.iter_mut().enumerate() {
            if j < i {
                *sij *= beta[start + i] * (g[i] / g[j]);
            } else {
                *sij = if j == i { 1.0 } else { 0.0 };
            }
        }
    }

    // RHS = diag(β) (V − diag(G) K S_in): one K_c @ S_in GEMM + scaling.
    let mut ks = Mat::zeros(len, dv);
    tensor::gemm_into(len, dk, dv, k.rows_data(start, end), &s_in.data, &mut ks.data, false);
    let mut rhs = Mat::zeros(len, dv);
    for i in 0..len {
        let bi = beta[start + i];
        let gi = g[i];
        let ksrow = ks.row(i);
        let vrow = v.row(start + i);
        for (j, r) in rhs.row_mut(i).iter_mut().enumerate() {
            *r = bi * (vrow[j] - gi * ksrow[j]);
        }
    }
    let w_hat = ops::solve_unit_lower(&sys, &rhs);

    // Outputs: O = diag(G) Q_c S_in + (tril(Q_c K_c^T) ⊙ Gratio) Ŵ —
    // two GEMMs plus a masked GEMM.
    let mut o = Mat::zeros(len, dv);
    tensor::gemm_diag_acc(len, dk, dv, &g, q.rows_data(start, end), &s_in.data, &mut o.data);
    let mut qk = Mat::zeros(len, len);
    tensor::gemm_nt_into(len, dk, len, q.rows_data(start, end), k.rows_data(start, end), &mut qk.data, false);
    for i in 0..len {
        let row = qk.row_mut(i);
        for (j, pij) in row.iter_mut().enumerate() {
            if j > i {
                *pij = 0.0;
            } else {
                *pij *= g[i] / g[j];
            }
        }
    }
    tensor::gemm_sparse_rows(len, len, dv, &qk.data, &w_hat.data, &mut o.data, true);

    // S_out = G_C S_in + K_c^T diag(G_C/G_s) Ŵ as one fused kernel.
    let g_c = g[len - 1];
    let mut s_out = s_in.scale(g_c);
    let wscale: Vec<f32> = g.iter().map(|&gs| g_c / gs).collect();
    tensor::gemm_tn_diag_acc(len, dk, dv, &wscale, k.rows_data(start, end), &w_hat.data, &mut s_out.data);
    ChunkOut { o, s_out }
}

/// Chunkwise Gated DeltaNet.
pub fn chunkwise(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32], c: usize) -> Mat {
    assert!(c >= 1);
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    let mut out = Mat::zeros(t, dv);
    let mut state = Mat::zeros(dk, dv);
    let mut start = 0;
    while start < t {
        let end = (start + c).min(t);
        let res = gdn_chunk(q, k, v, alpha, beta, start, end, &state);
        for i in 0..end - start {
            out.row_mut(start + i).copy_from_slice(res.o.row(i));
        }
        state = res.s_out;
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn parallel_equals_recurrent() {
        let mut rng = Rng::new(1);
        for &t in &[1usize, 2, 9, 32, 64] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &parallel(&x.q, &x.k, &x.v, &x.alpha, &x.beta),
                &recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta),
                1e-3,
                1e-3,
            );
        }
    }

    #[test]
    fn chunkwise_equals_recurrent() {
        let mut rng = Rng::new(2);
        let x = AttnInputs::random(70, 8, 6, &mut rng);
        let oracle = recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta);
        for &c in &[1usize, 4, 16, 70, 128] {
            assert_close(
                &chunkwise(&x.q, &x.k, &x.v, &x.alpha, &x.beta, c),
                &oracle,
                2e-3,
                2e-3,
            );
        }
    }

    #[test]
    fn gates_one_reduces_to_deltanet() {
        let mut rng = Rng::new(3);
        let t = 40;
        let x = AttnInputs::random(t, 8, 8, &mut rng);
        let ones = vec![1.0f32; t];
        assert_close(
            &recurrent(&x.q, &x.k, &x.v, &ones, &x.beta),
            &deltanet::recurrent(&x.q, &x.k, &x.v, &x.beta),
            1e-5,
            1e-5,
        );
    }

    #[test]
    fn beta_zero_reduces_to_pure_decay_of_nothing() {
        // β = 0: nothing is ever written; outputs are zero.
        let mut rng = Rng::new(4);
        let t = 16;
        let x = AttnInputs::random(t, 8, 8, &mut rng);
        let o = recurrent(&x.q, &x.k, &x.v, &x.alpha, &vec![0.0; t]);
        assert!(o.fro_norm() < 1e-7);
    }

    #[test]
    fn chunk_primitive_composes() {
        // Running [0,16) as one chunk == running [0,8) then [8,16).
        let mut rng = Rng::new(5);
        let x = AttnInputs::random(16, 6, 6, &mut rng);
        let s0 = Mat::zeros(6, 6);
        let full = gdn_chunk(&x.q, &x.k, &x.v, &x.alpha, &x.beta, 0, 16, &s0);
        let first = gdn_chunk(&x.q, &x.k, &x.v, &x.alpha, &x.beta, 0, 8, &s0);
        let second = gdn_chunk(&x.q, &x.k, &x.v, &x.alpha, &x.beta, 8, 16, &first.s_out);
        assert_close(&second.s_out, &full.s_out, 1e-3, 1e-3);
        for i in 0..8 {
            for j in 0..6 {
                assert!((full.o.at(i + 8, j) - second.o.at(i, j)).abs() < 1e-3);
            }
        }
    }
}
