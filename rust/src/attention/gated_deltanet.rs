//! Gated DeltaNet (Yang et al., 2024a): the delta rule composed with a
//! data-dependent scalar decay gate.
//!
//! Recurrence: `S_t = α_t (I − β_t k_t k_t^T) S_{t-1} + β_t k_t v_t^T`.
//!
//! Because the gates are scalars they commute with the Householder chain,
//! so the parallel form is exactly the paper's
//! `O = (T_K(QK^T) ⊙ M^S) V`: the ungated DeltaNet attention matrix
//! masked elementwise by the 1-semiseparable gate mask.
//!
//! The chunkwise form uses the numerically-stable scaled UT transform
//! (all intermediate ratios `G_t/G_s ≤ 1` for `s < t`): per chunk,
//! solve `(I + StrictTril(M)) Ŵ = diag(β)(V − diag(G) K S_in)` with
//! `M[t][s] = β_t (k_t·k_s) G_t/G_s`, then
//! `O = diag(G) Q S_in + (tril(QK^T) ⊙ Gratio) Ŵ` and
//! `S_out = G_C S_in + Σ_s (G_C/G_s) k_s ŵ_s^T`.

use crate::hmatrix::sss::SssMask;
use crate::tensor::{ops, Mat};

use super::deltanet;

/// Recurrent oracle.
pub fn recurrent(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32]) -> Mat {
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    assert_eq!(alpha.len(), t);
    assert_eq!(beta.len(), t);
    let mut s = Mat::zeros(dk, dv);
    let mut out = Mat::zeros(t, dv);
    for i in 0..t {
        deltanet::apply_householder(&mut s, k.row(i), beta[i]);
        s.scale_inplace(alpha[i]);
        crate::tensor::outer_acc(&mut s, k.row(i), v.row(i), beta[i]);
        out.row_mut(i).copy_from_slice(&s.matvec_t(q.row(i)));
    }
    out
}

/// Parallel form: `O = (A^δ ⊙ M^S) V` with `A^δ` the DeltaNet attention
/// matrix — scalar gates factor out of the Householder product.
pub fn parallel(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32]) -> Mat {
    let a = deltanet::attn_matrix(q, k, beta);
    let p = a.hadamard(&SssMask::new(alpha).dense());
    p.matmul(v)
}

/// Result of running one chunk: per-position outputs plus outgoing state.
pub struct ChunkOut {
    pub o: Mat,
    pub s_out: Mat,
}

/// The gated-delta chunk primitive (stable scaled UT transform).
///
/// Processes positions `[start, end)` given the state at chunk entry
/// (covering all transitions through `start-1`). Returns the chunk's
/// outputs and the state at chunk exit.
pub fn gdn_chunk(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    alpha: &[f32],
    beta: &[f32],
    start: usize,
    end: usize,
    s_in: &Mat,
) -> ChunkOut {
    let len = end - start;
    let dv = v.cols;
    // G[i] = Π_{j=start..start+i} α_j  (decay through position i, local).
    let mut g = vec![0.0f32; len];
    let mut acc = 1.0f64;
    for i in 0..len {
        acc *= alpha[start + i] as f64;
        g[i] = acc as f32;
    }

    // System matrix M (strict lower) with entries β_t (k_t·k_s) G_t/G_s.
    let mut sys = Mat::zeros(len, len);
    for i in 0..len {
        *sys.at_mut(i, i) = 1.0;
        for j in 0..i {
            *sys.at_mut(i, j) = beta[start + i]
                * crate::tensor::dot(k.row(start + i), k.row(start + j))
                * (g[i] / g[j]);
        }
    }

    // RHS = diag(β) (V − diag(G) K S_in)
    let mut rhs = Mat::zeros(len, dv);
    for i in 0..len {
        let ks = s_in.matvec_t(k.row(start + i)); // S_in^T k_i : (dv)
        for j in 0..dv {
            *rhs.at_mut(i, j) = beta[start + i] * (v.at(start + i, j) - g[i] * ks[j]);
        }
    }
    let w_hat = ops::solve_unit_lower(&sys, &rhs);

    // Outputs: o_t = G_t (S_in^T q_t) + Σ_{s≤t} (q_t·k_s)(G_t/G_s) ŵ_s
    let mut o = Mat::zeros(len, dv);
    for i in 0..len {
        let qi = q.row(start + i);
        let base = s_in.matvec_t(qi);
        let orow = o.row_mut(i);
        for j in 0..dv {
            orow[j] = g[i] * base[j];
        }
        for s in 0..=i {
            let w = crate::tensor::dot(qi, k.row(start + s)) * (g[i] / g[s]);
            for (dst, &x) in orow.iter_mut().zip(w_hat.row(s)) {
                *dst += w * x;
            }
        }
    }

    // S_out = G_C S_in + Σ_s (G_C/G_s) k_s ŵ_s^T
    let g_c = g[len - 1];
    let mut s_out = s_in.scale(g_c);
    for s in 0..len {
        let scale = g_c / g[s];
        let ks = k.row(start + s);
        for (i, &ki) in ks.iter().enumerate() {
            let c = scale * ki;
            if c == 0.0 {
                continue;
            }
            let row = &mut s_out.data[i * dv..(i + 1) * dv];
            for (r, &w) in row.iter_mut().zip(w_hat.row(s)) {
                *r += c * w;
            }
        }
    }
    ChunkOut { o, s_out }
}

/// Chunkwise Gated DeltaNet.
pub fn chunkwise(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32], c: usize) -> Mat {
    assert!(c >= 1);
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    let mut out = Mat::zeros(t, dv);
    let mut state = Mat::zeros(dk, dv);
    let mut start = 0;
    while start < t {
        let end = (start + c).min(t);
        let res = gdn_chunk(q, k, v, alpha, beta, start, end, &state);
        for i in 0..end - start {
            out.row_mut(start + i).copy_from_slice(res.o.row(i));
        }
        state = res.s_out;
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn parallel_equals_recurrent() {
        let mut rng = Rng::new(1);
        for &t in &[1usize, 2, 9, 32, 64] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &parallel(&x.q, &x.k, &x.v, &x.alpha, &x.beta),
                &recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta),
                1e-3,
                1e-3,
            );
        }
    }

    #[test]
    fn chunkwise_equals_recurrent() {
        let mut rng = Rng::new(2);
        let x = AttnInputs::random(70, 8, 6, &mut rng);
        let oracle = recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta);
        for &c in &[1usize, 4, 16, 70, 128] {
            assert_close(
                &chunkwise(&x.q, &x.k, &x.v, &x.alpha, &x.beta, c),
                &oracle,
                2e-3,
                2e-3,
            );
        }
    }

    #[test]
    fn gates_one_reduces_to_deltanet() {
        let mut rng = Rng::new(3);
        let t = 40;
        let x = AttnInputs::random(t, 8, 8, &mut rng);
        let ones = vec![1.0f32; t];
        assert_close(
            &recurrent(&x.q, &x.k, &x.v, &ones, &x.beta),
            &deltanet::recurrent(&x.q, &x.k, &x.v, &x.beta),
            1e-5,
            1e-5,
        );
    }

    #[test]
    fn beta_zero_reduces_to_pure_decay_of_nothing() {
        // β = 0: nothing is ever written; outputs are zero.
        let mut rng = Rng::new(4);
        let t = 16;
        let x = AttnInputs::random(t, 8, 8, &mut rng);
        let o = recurrent(&x.q, &x.k, &x.v, &x.alpha, &vec![0.0; t]);
        assert!(o.fro_norm() < 1e-7);
    }

    #[test]
    fn chunk_primitive_composes() {
        // Running [0,16) as one chunk == running [0,8) then [8,16).
        let mut rng = Rng::new(5);
        let x = AttnInputs::random(16, 6, 6, &mut rng);
        let s0 = Mat::zeros(6, 6);
        let full = gdn_chunk(&x.q, &x.k, &x.v, &x.alpha, &x.beta, 0, 16, &s0);
        let first = gdn_chunk(&x.q, &x.k, &x.v, &x.alpha, &x.beta, 0, 8, &s0);
        let second = gdn_chunk(&x.q, &x.k, &x.v, &x.alpha, &x.beta, 8, 16, &first.s_out);
        assert_close(&second.s_out, &full.s_out, 1e-3, 1e-3);
        for i in 0..8 {
            for j in 0..6 {
                assert!((full.o.at(i + 8, j) - second.o.at(i, j)).abs() < 1e-3);
            }
        }
    }
}
