//! Log-Linear Gated DeltaNet (paper §3.4): the delta rule + scalar gate,
//! lifted with the hierarchical mask,
//! `O = (T_K(QK^T) ⊙ M^S ⊙ M^H) V`.
//!
//! The recurrent form maintains `O(log T)` states that *all* evolve under
//! the same gated Householder transition `α_t (I − β_t k_t k_t^T)` —
//! transitions distribute over the bucket sum, which is why the Fenwick
//! merge stays valid for matrix-valued (identity-plus-low-rank)
//! transitions (App. A's `H`-tensor view).
//!
//! The chunkwise form drives the shared [`ChunkFenwick`] engine with the
//! Householder-chain chunk transition and uses the explicit local
//! attention matrix for the intra-chunk stage (the paper notes intra-chunk
//! needs bespoke treatment; masking by `Λ` must happen on the *materialized*
//! local `P`, since the UT solve mixes value rows otherwise).

use crate::fenwick;
use crate::tensor::{ops, outer_acc, Mat};

use super::deltanet::{apply_householder, apply_householder_vec, attn_matrix};
use super::loglinear::{local_lambda_mask, parallel_from_a, ChunkFenwick};

/// Token-granularity Fenwick recurrence (decode form).
pub fn recurrent(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32], lambda: &Mat) -> Mat {
    let (t_len, dk, dv) = (q.rows, q.cols, v.cols);
    let mut out = Mat::zeros(t_len, dv);
    let nl = fenwick::num_levels(t_len.max(1));
    let mut levels: Vec<Option<Mat>> = vec![None; nl + 1];
    for t in 0..t_len {
        // merge
        if t > 0 {
            let l = fenwick::lssb(t) as usize;
            let mut merged: Option<Mat> = None;
            for s in levels.iter_mut().take(l + 1) {
                if let Some(m) = s.take() {
                    match merged {
                        None => merged = Some(m),
                        Some(ref mut acc) => acc.axpy(1.0, &m),
                    }
                }
            }
            if let Some(m) = merged {
                debug_assert!(levels[l + 1].is_none());
                levels[l + 1] = Some(m);
            }
        }
        // transition all carried states: S ← α_t (I − β_t k_t k_t^T) S
        for s in levels.iter_mut().flatten() {
            apply_householder(s, k.row(t), beta[t]);
            s.scale_inplace(alpha[t]);
        }
        // sentinel: β_t k_t v_t^T
        let mut s0 = Mat::zeros(dk, dv);
        outer_acc(&mut s0, k.row(t), v.row(t), beta[t]);
        levels[0] = Some(s0);
        // read
        let orow = out.row_mut(t);
        for (l, s) in levels.iter().enumerate() {
            if let Some(s) = s {
                let lam = lambda.at(t, l);
                if lam == 0.0 {
                    continue;
                }
                for (dst, x) in orow.iter_mut().zip(s.matvec_t(q.row(t))) {
                    *dst += lam * x;
                }
            }
        }
    }
    out
}

/// Parallel form: `O = (A^δ ⊙ QuasiH(α, λ)) V`.
pub fn parallel(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32], lambda: &Mat) -> Mat {
    let a = attn_matrix(q, k, beta);
    parallel_from_a(&a, alpha, lambda, v)
}

/// Materialized local gated-delta attention matrix for one chunk:
/// `P = (tril(Q K^T) ⊙ Gratio) (I + StrictTril(M))^{-1} diag(β)` with
/// `M[i][j] = β_i (k_i·k_j) G_i/G_j`. O(C^3) per chunk — the bespoke
/// intra-chunk stage.
fn local_p_matrix(
    q: &Mat,
    k: &Mat,
    alpha: &[f32],
    beta: &[f32],
    start: usize,
    len: usize,
) -> (Mat, Vec<f32>) {
    // local decays
    let mut g = vec![0.0f32; len];
    let mut acc = 1.0f64;
    for i in 0..len {
        acc *= alpha[start + i] as f64;
        g[i] = acc as f32;
    }
    let mut sys = Mat::zeros(len, len);
    for i in 0..len {
        *sys.at_mut(i, i) = 1.0;
        for j in 0..i {
            *sys.at_mut(i, j) = beta[start + i]
                * crate::tensor::dot(k.row(start + i), k.row(start + j))
                * (g[i] / g[j]);
        }
    }
    let mut qk = Mat::zeros(len, len);
    for i in 0..len {
        for j in 0..=i {
            *qk.at_mut(i, j) =
                crate::tensor::dot(q.row(start + i), k.row(start + j)) * (g[i] / g[j]);
        }
    }
    // P = qk sys^{-1} diag(β): solve sys^T Y = qk^T, P[i][j] = β_j Y[j][i].
    let y = ops::solve_unit_upper(&sys.transpose(), &qk.transpose());
    let p = Mat::from_fn(len, len, |i, j| beta[start + j] * y.at(j, i));
    (p, g)
}

/// Chunkwise form (Algorithm 1 with Householder-chain transitions).
pub fn chunkwise(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    alpha: &[f32],
    beta: &[f32],
    lambda: &Mat,
    c: usize,
) -> Mat {
    assert!(c >= 1 && c.is_power_of_two(), "chunk size must be a power of two");
    let (t_len, dk, dv) = (q.rows, q.cols, v.cols);
    let lc = c.trailing_zeros() as usize;
    let mut out = Mat::zeros(t_len, dv);
    let mut eng = ChunkFenwick::new();
    let mut z = 0usize;
    let mut start = 0usize;
    while start < t_len {
        let end = (start + c).min(t_len);
        let len = end - start;
        eng.advance(z);

        // ---- intra-chunk: (P_local ⊙ Λ_local) V_local ----
        let (p_loc, g) = local_p_matrix(q, k, alpha, beta, start, len);
        let lam_loc = local_lambda_mask(lambda, start, len);
        let p_masked = p_loc.hadamard(&lam_loc);
        for i in 0..len {
            let mut acc_row = vec![0.0f32; dv];
            for j in 0..=i {
                let w = p_masked.at(i, j);
                if w == 0.0 {
                    continue;
                }
                for (a, &vv) in acc_row.iter_mut().zip(v.row(start + j)) {
                    *a += w * vv;
                }
            }
            out.row_mut(start + i).copy_from_slice(&acc_row);
        }

        // ---- inter-chunk reads with effective queries ----
        // q̂_t = G_t · Φ_start ··· Φ_t q_t (apply Φ from t down to start).
        for i in 0..len {
            let mut qe = q.row(start + i).to_vec();
            for j in (0..=i).rev() {
                apply_householder_vec(&mut qe, k.row(start + j), beta[start + j]);
            }
            for x in qe.iter_mut() {
                *x *= g[i];
            }
            let orow = out.row_mut(start + i);
            for (m, s) in eng.active() {
                let lam = lambda.at(start + i, lc + m);
                if lam == 0.0 {
                    continue;
                }
                for (dst, x) in orow.iter_mut().zip(s.matvec_t(&qe)) {
                    *dst += lam * x;
                }
            }
        }

        // ---- chunk state write (own contribution, S_in = 0) ----
        let res = super::gated_deltanet::gdn_chunk(
            q, k, v, alpha, beta, start, end, &Mat::zeros(dk, dv),
        );

        // ---- transition carried states through this chunk ----
        let chunk_decay = g[len - 1];
        eng.apply_transition(|s| {
            for j in 0..len {
                apply_householder(s, k.row(start + j), beta[start + j]);
            }
            s.scale_inplace(chunk_decay);
        });
        eng.set_level0(res.s_out);

        z += 1;
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn parallel_equals_recurrent() {
        let mut rng = Rng::new(1);
        for &t in &[1usize, 2, 7, 16, 33, 64] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &parallel(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda),
                &recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda),
                1e-3,
                1e-3,
            );
        }
    }

    #[test]
    fn chunkwise_equals_recurrent() {
        let mut rng = Rng::new(2);
        for &(t, c) in &[(64usize, 8usize), (100, 16), (48, 4), (16, 16), (24, 1)] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            let oracle = recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda);
            assert_close(
                &chunkwise(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda, c),
                &oracle,
                2e-3,
                2e-3,
            );
        }
    }

    #[test]
    fn local_p_matches_global_attn_matrix_first_chunk() {
        // For the first chunk (no history), the local P must equal the
        // global gated attention matrix restricted to the chunk.
        let mut rng = Rng::new(3);
        let t = 16;
        let x = AttnInputs::random(t, 6, 6, &mut rng);
        let (p, _) = local_p_matrix(&x.q, &x.k, &x.alpha, &x.beta, 0, 8);
        let a = attn_matrix(&x.q, &x.k, &x.beta);
        let sss = crate::hmatrix::sss::SssMask::new(&x.alpha).dense();
        for i in 0..8 {
            for j in 0..=i {
                let expect = a.at(i, j) * sss.at(i, j);
                assert!(
                    (p.at(i, j) - expect).abs() < 1e-4,
                    "({i},{j}): {} vs {}",
                    p.at(i, j),
                    expect
                );
            }
        }
    }
}
