//! Log-Linear Gated DeltaNet (paper §3.4): the delta rule + scalar gate,
//! lifted with the hierarchical mask,
//! `O = (T_K(QK^T) ⊙ M^S ⊙ M^H) V`.
//!
//! The recurrent form maintains `O(log T)` states that *all* evolve under
//! the same gated Householder transition `α_t (I − β_t k_t k_t^T)` —
//! transitions distribute over the bucket sum, which is why the Fenwick
//! merge stays valid for matrix-valued (identity-plus-low-rank)
//! transitions (App. A's `H`-tensor view).
//!
//! The chunkwise form drives the shared [`ChunkFenwick`] engine in its
//! matmul-rich mode: the per-chunk UT system comes from one `K_c K_c^T`
//! GEMM, all `O(log T/C)` level reads happen in a single
//! `Q̂_c @ S_cat` GEMM over the effective queries (themselves UT-derived
//! from the intra-chunk solve — `q̂_i = G_i q_i − Σ_{j≤i} P_ij G_j k_j`,
//! one GEMM per chunk instead of a per-row Householder sweep), the chunk state write
//! is one fused `K_c^T diag(w) Ŵ` kernel, and the carried states are
//! advanced with a *materialized* chunk transition
//! `Φ_chunk = G_C · Φ_{C-1}···Φ_0` applied as one `(d_k,d_k)` GEMM per
//! state instead of `C` rank-1 updates per state. Intra-chunk attention
//! masks the *materialized* local `P` by `Λ` (the paper notes intra-chunk
//! needs bespoke treatment; the UT solve mixes value rows otherwise).

use crate::fenwick;
use crate::tensor::{self, ops, outer_acc, Mat};

use super::deltanet::{apply_householder, attn_matrix};
use super::loglinear::{local_lambda_mask, parallel_from_a, ChunkFenwick};

/// Token-granularity Fenwick recurrence (decode form).
pub fn recurrent(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32], lambda: &Mat) -> Mat {
    let (t_len, dk, dv) = (q.rows, q.cols, v.cols);
    let mut out = Mat::zeros(t_len, dv);
    let nl = fenwick::num_levels(t_len.max(1));
    let mut levels: Vec<Option<Mat>> = vec![None; nl + 1];
    for t in 0..t_len {
        // merge
        if t > 0 {
            let l = fenwick::lssb(t) as usize;
            let mut merged: Option<Mat> = None;
            for s in levels.iter_mut().take(l + 1) {
                if let Some(m) = s.take() {
                    match merged {
                        None => merged = Some(m),
                        Some(ref mut acc) => acc.axpy(1.0, &m),
                    }
                }
            }
            if let Some(m) = merged {
                debug_assert!(levels[l + 1].is_none());
                levels[l + 1] = Some(m);
            }
        }
        // transition all carried states: S ← α_t (I − β_t k_t k_t^T) S
        for s in levels.iter_mut().flatten() {
            apply_householder(s, k.row(t), beta[t]);
            s.scale_inplace(alpha[t]);
        }
        // sentinel: β_t k_t v_t^T
        let mut s0 = Mat::zeros(dk, dv);
        outer_acc(&mut s0, k.row(t), v.row(t), beta[t]);
        levels[0] = Some(s0);
        // read (fused, no temporaries)
        let orow = out.row_mut(t);
        for (l, s) in levels.iter().enumerate() {
            if let Some(s) = s {
                let lam = lambda.at(t, l);
                if lam == 0.0 {
                    continue;
                }
                s.matvec_t_acc(q.row(t), lam, orow);
            }
        }
    }
    out
}

/// Parallel form: `O = (A^δ ⊙ QuasiH(α, λ)) V`.
pub fn parallel(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], beta: &[f32], lambda: &Mat) -> Mat {
    let a = attn_matrix(q, k, beta);
    parallel_from_a(&a, alpha, lambda, v)
}

/// Local cumulative decays: `g[i] = Π_{j=start..start+i} α_j`.
fn local_decays(alpha: &[f32], start: usize, len: usize) -> Vec<f32> {
    let mut g = vec![0.0f32; len];
    let mut acc = 1.0f64;
    for i in 0..len {
        acc *= alpha[start + i] as f64;
        g[i] = acc as f32;
    }
    g
}

/// The chunk's UT system `I + StrictTril(M)`,
/// `M[i][j] = β_i (k_i·k_j) G_i/G_j`, built from one `K_c K_c^T` GEMM
/// plus an O(len²) scaling pass.
fn chunk_ut_system(k: &Mat, beta: &[f32], g: &[f32], start: usize, len: usize) -> Mat {
    let dk = k.cols;
    let mut sys = Mat::zeros(len, len);
    tensor::gemm_nt_into(
        len,
        dk,
        len,
        k.rows_data(start, start + len),
        k.rows_data(start, start + len),
        &mut sys.data,
        false,
    );
    for i in 0..len {
        let bi = beta[start + i];
        let gi = g[i];
        let row = sys.row_mut(i);
        for (j, sij) in row.iter_mut().enumerate() {
            if j < i {
                *sij *= bi * (gi / g[j]);
            } else {
                *sij = if j == i { 1.0 } else { 0.0 };
            }
        }
    }
    sys
}

/// Materialized local gated-delta attention matrix for one chunk:
/// `P = (tril(Q K^T) ⊙ Gratio) (I + StrictTril(M))^{-1} diag(β)` —
/// O(C^3) per chunk, GEMM-built.
#[allow(clippy::too_many_arguments)]
fn local_p_from_sys(
    q: &Mat,
    k: &Mat,
    beta: &[f32],
    g: &[f32],
    sys: &Mat,
    start: usize,
    len: usize,
) -> Mat {
    let dk = k.cols;
    let mut qk = Mat::zeros(len, len);
    tensor::gemm_nt_into(
        len,
        dk,
        len,
        q.rows_data(start, start + len),
        k.rows_data(start, start + len),
        &mut qk.data,
        false,
    );
    for i in 0..len {
        let gi = g[i];
        let row = qk.row_mut(i);
        for (j, pij) in row.iter_mut().enumerate() {
            if j > i {
                *pij = 0.0;
            } else {
                *pij *= gi / g[j];
            }
        }
    }
    // P = qk sys^{-1} diag(β): solve sys^T Y = qk^T, P[i][j] = β_j Y[j][i].
    let y = ops::solve_unit_upper(&sys.transpose(), &qk.transpose());
    Mat::from_fn(len, len, |i, j| beta[start + j] * y.at(j, i))
}

/// Effective queries for one chunk via the UT transform: the per-row
/// gated Householder chain `q̂_i = G_i · Φ_start ⋯ Φ_i q_i` — an
/// O(C²·d_k) *scalar* rank-1 sweep — collapses against the **unmasked**
/// local `P = (tril(QK^T) ⊙ Gratio)(I + StrictTril(M))^{-1} diag(β)` to
///
/// `q̂_i = G_i q_i − Σ_{j≤i} P_ij G_j k_j`
///
/// (P's `diag(β)` column fold carries each reflection's `β_j`; the
/// Gratio similarity turns the ungated UT coefficients into `P_ij G_j /
/// G_i`, and the leading `G_i` cancels it). One `(len,len)·(len,d_k)`
/// GEMM per chunk, sharing the triangular solve the intra-chunk term
/// already pays for. `kb` and `qe` are caller workspaces with ≥ `len`
/// rows of width `d_k`; rows `0..len` of `qe` receive `Q̂`.
fn effective_queries_from_p(
    q: &Mat,
    k: &Mat,
    g: &[f32],
    p: &Mat,
    start: usize,
    len: usize,
    kb: &mut Mat,
    qe: &mut Mat,
) {
    let dk = k.cols;
    debug_assert_eq!(p.rows * p.cols, len * len);
    for i in 0..len {
        let gi = g[i];
        for (x, &qv) in qe.row_mut(i).iter_mut().zip(q.row(start + i)) {
            *x = gi * qv;
        }
        let w = -g[i];
        for (x, &kv) in kb.row_mut(i).iter_mut().zip(k.row(start + i)) {
            *x = w * kv;
        }
    }
    tensor::gemm_sparse_rows(
        len,
        len,
        dk,
        &p.data[..len * len],
        &kb.data[..len * dk],
        &mut qe.data[..len * dk],
        true,
    );
}

/// `P` and local decays for one chunk (the bespoke intra-chunk stage).
fn local_p_matrix(
    q: &Mat,
    k: &Mat,
    alpha: &[f32],
    beta: &[f32],
    start: usize,
    len: usize,
) -> (Mat, Vec<f32>) {
    let g = local_decays(alpha, start, len);
    let sys = chunk_ut_system(k, beta, &g, start, len);
    let p = local_p_from_sys(q, k, beta, &g, &sys, start, len);
    (p, g)
}

/// Chunkwise form (Algorithm 1 with Householder-chain transitions).
pub fn chunkwise(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    alpha: &[f32],
    beta: &[f32],
    lambda: &Mat,
    c: usize,
) -> Mat {
    assert!(c >= 1 && c.is_power_of_two(), "chunk size must be a power of two");
    let (t_len, dk, dv) = (q.rows, q.cols, v.cols);
    let lc = c.trailing_zeros() as usize;
    let mut out = Mat::zeros(t_len, dv);
    let mut eng = ChunkFenwick::new();
    // reusable per-chunk workspaces
    let cmax = c.min(t_len.max(1));
    let mut qe = Mat::zeros(cmax, dk); // effective queries Q̂_c
    let mut kb = Mat::zeros(cmax, dk); // −G_j-scaled key rows for the Q̂ GEMM
    let mut phi = Mat::zeros(dk, dk); // materialized chunk transition
    let mut wscale = vec![0.0f32; cmax];
    let mut z = 0usize;
    let mut start = 0usize;
    while start < t_len {
        let end = (start + c).min(t_len);
        let len = end - start;
        eng.advance(z);

        let g = local_decays(alpha, start, len);
        let sys = chunk_ut_system(k, beta, &g, start, len);

        // ---- intra-chunk: (P_local ⊙ Λ_local) V_local ----
        // Λ-mask the materialized P in place, then one masked GEMM. The
        // inter-chunk effective queries ride on the SAME solve, read off
        // the unmasked P before the Λ fold.
        let mut p = local_p_from_sys(q, k, beta, &g, &sys, start, len);
        effective_queries_from_p(q, k, &g, &p, start, len, &mut kb, &mut qe);
        for i in 0..len {
            let row = p.row_mut(i);
            for (j, pij) in row.iter_mut().enumerate() {
                if j > i {
                    *pij = 0.0;
                } else {
                    *pij *= lambda.at(start + i, fenwick::level_of(i, j));
                }
            }
        }
        tensor::gemm_sparse_rows(
            len,
            len,
            dv,
            &p.data,
            v.rows_data(start, end),
            out.rows_data_mut(start, end),
            true,
        );

        // ---- inter-chunk reads, batched ----
        // Effective queries q̂_t = G_t · Φ_start ··· Φ_t q_t
        // (UT-transformed above), all levels in one Q̂_c @ S_cat GEMM.
        eng.read_levels_into(qe.rows_data(0, len), len, &mut out, start, |i, m| {
            lambda.at(start + i, lc + m)
        });

        // ---- chunk state write (own contribution, S_in = 0) ----
        // Ŵ = (I + StrictTril(M))^{-1} diag(β) V_c, then
        // S_new = K_c^T diag(G_C/G_s) Ŵ as one fused kernel.
        let mut rhs = Mat::zeros(len, dv);
        for i in 0..len {
            let bi = beta[start + i];
            for (r, &vv) in rhs.row_mut(i).iter_mut().zip(v.row(start + i)) {
                *r = bi * vv;
            }
        }
        let w_hat = ops::solve_unit_lower(&sys, &rhs);
        let g_c = g[len - 1];
        for s in 0..len {
            wscale[s] = g_c / g[s];
        }
        let mut s_new = eng.take_buffer(dk, dv);
        tensor::gemm_tn_diag_acc(
            len,
            dk,
            dv,
            &wscale[..len],
            k.rows_data(start, end),
            &w_hat.data,
            &mut s_new.data,
        );

        // ---- transition carried states through this chunk ----
        // Materialize Φ_chunk = G_C · Φ_{end-1} ··· Φ_start once, then one
        // (d_k, d_k) GEMM per live state instead of C rank-1 sweeps each.
        phi.data.fill(0.0);
        for i in 0..dk {
            *phi.at_mut(i, i) = 1.0;
        }
        for j in 0..len {
            apply_householder(&mut phi, k.row(start + j), beta[start + j]);
        }
        phi.scale_inplace(g_c);
        eng.apply_matrix_transition(&phi);
        eng.set_level0(s_new);

        z += 1;
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn parallel_equals_recurrent() {
        let mut rng = Rng::new(1);
        for &t in &[1usize, 2, 7, 16, 33, 64] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &parallel(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda),
                &recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda),
                1e-3,
                1e-3,
            );
        }
    }

    #[test]
    fn chunkwise_equals_recurrent() {
        let mut rng = Rng::new(2);
        for &(t, c) in &[(64usize, 8usize), (100, 16), (48, 4), (16, 16), (24, 1)] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            let oracle = recurrent(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda);
            assert_close(
                &chunkwise(&x.q, &x.k, &x.v, &x.alpha, &x.beta, &x.lambda, c),
                &oracle,
                2e-3,
                2e-3,
            );
        }
    }

    #[test]
    fn ut_effective_queries_match_householder_chain() {
        // The UT-transformed effective queries must agree with the scalar
        // gated-Householder chain they replaced, within solver tolerance
        // — across chunk offsets, a non-power-of-two tail length, and the
        // len == 1 degenerate chunk.
        use crate::attention::deltanet::apply_householder_vec;
        let mut rng = Rng::new(6);
        for &(start, len) in &[(0usize, 8usize), (8, 8), (16, 5), (0, 1)] {
            let t = 24;
            let x = AttnInputs::random(t, 6, 6, &mut rng);
            let g = local_decays(&x.alpha, start, len);
            let sys = chunk_ut_system(&x.k, &x.beta, &g, start, len);
            let p = local_p_from_sys(&x.q, &x.k, &x.beta, &g, &sys, start, len);
            let mut qe = Mat::zeros(len, x.q.cols);
            let mut kb = Mat::zeros(len, x.q.cols);
            effective_queries_from_p(&x.q, &x.k, &g, &p, start, len, &mut kb, &mut qe);

            let mut want = Mat::zeros(len, x.q.cols);
            for i in 0..len {
                let row = want.row_mut(i);
                row.copy_from_slice(x.q.row(start + i));
                for j in (0..=i).rev() {
                    apply_householder_vec(row, x.k.row(start + j), x.beta[start + j]);
                }
                for v in row.iter_mut() {
                    *v *= g[i];
                }
            }
            assert_close(&qe, &want, 1e-4, 1e-4);
        }
    }

    #[test]
    fn local_p_matches_global_attn_matrix_first_chunk() {
        // For the first chunk (no history), the local P must equal the
        // global gated attention matrix restricted to the chunk.
        let mut rng = Rng::new(3);
        let t = 16;
        let x = AttnInputs::random(t, 6, 6, &mut rng);
        let (p, _) = local_p_matrix(&x.q, &x.k, &x.alpha, &x.beta, 0, 8);
        let a = attn_matrix(&x.q, &x.k, &x.beta);
        let sss = crate::hmatrix::sss::SssMask::new(&x.alpha).dense();
        for i in 0..8 {
            for j in 0..=i {
                let expect = a.at(i, j) * sss.at(i, j);
                assert!(
                    (p.at(i, j) - expect).abs() < 1e-4,
                    "({i},{j}): {} vs {}",
                    p.at(i, j),
                    expect
                );
            }
        }
    }

    #[test]
    fn materialized_chunk_transition_matches_sequential_householders() {
        // Φ_chunk S must equal applying the per-token gated Householder
        // chain to S directly (the rewrite the chunkwise form relies on).
        let mut rng = Rng::new(4);
        let (dk, dv, len) = (6, 5, 8);
        let x = AttnInputs::random(len, dk, dv, &mut rng);
        let s0 = Mat::randn(dk, dv, 1.0, &mut rng);

        // sequential: S ← α_j (I − β_j k_j k_j^T) S, j ascending
        let mut seq = s0.clone();
        let mut g_c = 1.0f32;
        for j in 0..len {
            apply_householder(&mut seq, x.k.row(j), x.beta[j]);
            g_c *= x.alpha[j];
        }
        seq.scale_inplace(g_c);

        // materialized
        let mut phi = Mat::eye(dk);
        for j in 0..len {
            apply_householder(&mut phi, x.k.row(j), x.beta[j]);
        }
        phi.scale_inplace(g_c);
        assert_close(&phi.matmul(&s0), &seq, 1e-4, 1e-4);
    }

    #[test]
    fn local_lambda_mask_agrees_with_inline_masking() {
        // The chunkwise path masks P inline via level_of; it must match
        // hadamard with the materialized local_lambda_mask.
        let mut rng = Rng::new(5);
        let t = 24;
        let x = AttnInputs::random(t, 6, 6, &mut rng);
        let (start, len) = (8, 8);
        let (p, _) = local_p_matrix(&x.q, &x.k, &x.alpha, &x.beta, start, len);
        let want = p.hadamard(&local_lambda_mask(&x.lambda, start, len));
        let mut got = p.clone();
        for i in 0..len {
            let row = got.row_mut(i);
            for (j, pij) in row.iter_mut().enumerate() {
                if j > i {
                    *pij = 0.0;
                } else {
                    *pij *= x.lambda.at(start + i, fenwick::level_of(i, j));
                }
            }
        }
        assert_close(&got, &want, 1e-6, 0.0);
    }
}
