//! Vanilla (ungated) linear attention (Katharopoulos et al., 2020), in the
//! three algorithmic forms of the paper's §2. No feature map, no
//! normalizer, matching the paper's working definition (footnote 4).

use crate::tensor::{self, outer_acc, Mat};

/// Recurrent form: `S_t = S_{t-1} + k_t v_t^T`, `o_t = S_t^T q_t`.
/// Linear time, constant memory — the oracle.
pub fn recurrent(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    let mut s = Mat::zeros(dk, dv);
    let mut out = Mat::zeros(t, dv);
    for i in 0..t {
        outer_acc(&mut s, k.row(i), v.row(i), 1.0);
        let o = s.matvec_t(q.row(i));
        out.row_mut(i).copy_from_slice(&o);
    }
    out
}

/// Parallel (masked) form: `O = (Q K^T ⊙ L) V` with the all-ones causal
/// mask `L`. Quadratic compute; used for training-style parallelism.
pub fn parallel(q: &Mat, k: &Mat, v: &Mat) -> Mat {
    let t = q.rows;
    let mut p = q.matmul_nt(k);
    for i in 0..t {
        for j in i + 1..t {
            *p.at_mut(i, j) = 0.0;
        }
    }
    p.matmul_sparse_rows(v)
}

/// Chunkwise form: intra-chunk quadratic + inter-chunk state passing
/// (the `O(T)` training algorithm the paper's Alg. 1 generalizes).
/// Matmul-rich: inter-chunk reads are one `Q_c @ S` GEMM, intra-chunk is
/// `Q_c K_c^T` + masked `P V_c`, and the state write is one `K_c^T V_c`.
pub fn chunkwise(q: &Mat, k: &Mat, v: &Mat, c: usize) -> Mat {
    assert!(c >= 1);
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    let mut out = Mat::zeros(t, dv);
    let mut state = Mat::zeros(dk, dv); // state entering the current chunk
    let cmax = c.min(t.max(1));
    let mut pbuf = vec![0.0f32; cmax * cmax];
    let mut chunk_start = 0;
    while chunk_start < t {
        let chunk_end = (chunk_start + c).min(t);
        let len = chunk_end - chunk_start;
        // Inter-chunk: o_t += state^T q_t  (state frozen at chunk entry).
        tensor::gemm_into(
            len,
            dk,
            dv,
            q.rows_data(chunk_start, chunk_end),
            &state.data,
            out.rows_data_mut(chunk_start, chunk_end),
            true,
        );
        // Intra-chunk: (Q_c K_c^T ⊙ L) V_c via a GEMM + tril mask + masked GEMM.
        let p = &mut pbuf[..len * len];
        tensor::gemm_nt_into(
            len,
            dk,
            len,
            q.rows_data(chunk_start, chunk_end),
            k.rows_data(chunk_start, chunk_end),
            p,
            false,
        );
        for i in 0..len {
            for pij in p[i * len + i + 1..(i + 1) * len].iter_mut() {
                *pij = 0.0;
            }
        }
        tensor::gemm_sparse_rows(
            len,
            len,
            dv,
            p,
            v.rows_data(chunk_start, chunk_end),
            out.rows_data_mut(chunk_start, chunk_end),
            true,
        );
        // State update: S += K_c^T V_c, one fused kernel.
        tensor::gemm_tn_into(
            len,
            dk,
            dv,
            k.rows_data(chunk_start, chunk_end),
            v.rows_data(chunk_start, chunk_end),
            &mut state.data,
            true,
        );
        chunk_start = chunk_end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn parallel_equals_recurrent() {
        let mut rng = Rng::new(1);
        for &t in &[1usize, 2, 17, 64] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &parallel(&x.q, &x.k, &x.v),
                &recurrent(&x.q, &x.k, &x.v),
                1e-4,
                1e-4,
            );
        }
    }

    #[test]
    fn chunkwise_equals_recurrent_various_chunks() {
        let mut rng = Rng::new(2);
        let x = AttnInputs::random(50, 8, 6, &mut rng);
        let oracle = recurrent(&x.q, &x.k, &x.v);
        for &c in &[1usize, 3, 8, 16, 50, 64] {
            assert_close(&chunkwise(&x.q, &x.k, &x.v, c), &oracle, 1e-4, 1e-4);
        }
    }

    #[test]
    fn single_token() {
        let mut rng = Rng::new(3);
        let x = AttnInputs::random(1, 4, 4, &mut rng);
        let o = recurrent(&x.q, &x.k, &x.v);
        // o_0 = (q_0 . k_0) v_0
        let w = crate::tensor::dot(x.q.row(0), x.k.row(0));
        for j in 0..4 {
            assert!((o.at(0, j) - w * x.v.at(0, j)).abs() < 1e-5);
        }
    }
}
