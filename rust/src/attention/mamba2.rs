//! Mamba-2 / SSD: linear attention with a data-dependent scalar gate
//! (Dao & Gu, 2024). Mask `M^S` is 1-semiseparable (paper Eq. 2).
//!
//! Recurrence: `S_t = α_t S_{t-1} + k_t v_t^T`, `o_t = S_t^T q_t`.
//! The chunkwise form here is the standard SSD algorithm — the O(T)
//! "state-passing primitive" that Algorithm 1 invokes O(log T/C) times.

use crate::hmatrix::sss::SssMask;
use crate::tensor::{self, outer_acc, Mat};

/// Recurrent oracle.
pub fn recurrent(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32]) -> Mat {
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    assert_eq!(alpha.len(), t);
    let mut s = Mat::zeros(dk, dv);
    let mut out = Mat::zeros(t, dv);
    for i in 0..t {
        s.scale_inplace(alpha[i]);
        outer_acc(&mut s, k.row(i), v.row(i), 1.0);
        let o = s.matvec_t(q.row(i));
        out.row_mut(i).copy_from_slice(&o);
    }
    out
}

/// Parallel (masked) form: `O = (Q K^T ⊙ M^S) V`.
pub fn parallel(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32]) -> Mat {
    let p = q.matmul_nt(k).hadamard(&SssMask::new(alpha).dense());
    p.matmul_sparse_rows(v)
}

/// Chunkwise (SSD) form with chunk size `c`, matmul-rich: per chunk,
/// (1) intra-chunk masked attention as `Q_c K_c^T` + masked `P V_c`
/// GEMMs, (2) inter-chunk contribution as one fused
/// `diag(dec) · Q_c @ S_in` GEMM, (3) state update as one fused
/// `K_c^T diag(w) V_c` kernel. Workspaces are reused across chunks.
pub fn chunkwise(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], c: usize) -> Mat {
    assert!(c >= 1);
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    assert_eq!(alpha.len(), t);
    let mut out = Mat::zeros(t, dv);
    let mut state = Mat::zeros(dk, dv);
    let cmax = c.min(t.max(1));
    let mut pbuf = vec![0.0f32; cmax * cmax];
    let mut dec_in = vec![0.0f32; cmax];
    let mut wscale = vec![0.0f32; cmax];
    let mut start = 0;
    while start < t {
        let end = (start + c).min(t);
        let len = end - start;
        // Local cumulative decay: dec_in[i] = Π_{j=start..start+i} α_j
        // (decay from chunk entry *through* position i).
        let mut acc = 1.0f64;
        for i in 0..len {
            acc *= alpha[start + i] as f64;
            dec_in[i] = acc as f32;
        }
        let chunk_decay = dec_in[len - 1];

        // (2) inter-chunk reads: out_c += diag(dec_in) · Q_c @ S_in.
        tensor::gemm_diag_acc(
            len,
            dk,
            dv,
            &dec_in[..len],
            q.rows_data(start, end),
            &state.data,
            out.rows_data_mut(start, end),
        );
        // (1) intra-chunk: P = Q_c K_c^T, masked in place by
        // weight(i,j) = dec_in[i]/dec_in[j] (tril), then out_c += P V_c.
        let p = &mut pbuf[..len * len];
        tensor::gemm_nt_into(len, dk, len, q.rows_data(start, end), k.rows_data(start, end), p, false);
        for i in 0..len {
            let prow = &mut p[i * len..(i + 1) * len];
            for (j, pij) in prow.iter_mut().enumerate() {
                if j > i {
                    *pij = 0.0;
                } else {
                    *pij *= dec_in[i] / dec_in[j];
                }
            }
        }
        tensor::gemm_sparse_rows(len, len, dv, p, v.rows_data(start, end), out.rows_data_mut(start, end), true);
        // (3) state update: S ← chunk_decay·S + K_c^T diag(w) V_c with
        // w_j = decay from position j+1 .. end-1 = chunk_decay / dec_in[j].
        state.scale_inplace(chunk_decay);
        for j in 0..len {
            wscale[j] = chunk_decay / dec_in[j];
        }
        tensor::gemm_tn_diag_acc(
            len,
            dk,
            dv,
            &wscale[..len],
            k.rows_data(start, end),
            v.rows_data(start, end),
            &mut state.data,
        );
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn parallel_equals_recurrent() {
        let mut rng = Rng::new(1);
        for &t in &[1usize, 5, 33, 64] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &parallel(&x.q, &x.k, &x.v, &x.alpha),
                &recurrent(&x.q, &x.k, &x.v, &x.alpha),
                1e-4,
                1e-4,
            );
        }
    }

    #[test]
    fn chunkwise_equals_recurrent() {
        let mut rng = Rng::new(2);
        let x = AttnInputs::random(70, 8, 6, &mut rng);
        let oracle = recurrent(&x.q, &x.k, &x.v, &x.alpha);
        for &c in &[1usize, 4, 16, 70, 128] {
            assert_close(&chunkwise(&x.q, &x.k, &x.v, &x.alpha, c), &oracle, 1e-3, 1e-3);
        }
    }

    #[test]
    fn strong_decay_forgets_distant_past() {
        // With tiny gates, output at t is dominated by the current token:
        // o_t ≈ (q_t . k_t) v_t.
        let mut rng = Rng::new(3);
        let t = 16;
        let mut x = AttnInputs::random(t, 8, 8, &mut rng);
        x.alpha = vec![1e-4; t];
        let o = recurrent(&x.q, &x.k, &x.v, &x.alpha);
        for i in 0..t {
            let w = crate::tensor::dot(x.q.row(i), x.k.row(i));
            for j in 0..8 {
                assert!((o.at(i, j) - w * x.v.at(i, j)).abs() < 1e-2);
            }
        }
    }
}
