//! Mamba-2 / SSD: linear attention with a data-dependent scalar gate
//! (Dao & Gu, 2024). Mask `M^S` is 1-semiseparable (paper Eq. 2).
//!
//! Recurrence: `S_t = α_t S_{t-1} + k_t v_t^T`, `o_t = S_t^T q_t`.
//! The chunkwise form here is the standard SSD algorithm — the O(T)
//! "state-passing primitive" that Algorithm 1 invokes O(log T/C) times.

use crate::hmatrix::sss::SssMask;
use crate::tensor::{outer_acc, Mat};

/// Recurrent oracle.
pub fn recurrent(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32]) -> Mat {
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    assert_eq!(alpha.len(), t);
    let mut s = Mat::zeros(dk, dv);
    let mut out = Mat::zeros(t, dv);
    for i in 0..t {
        s.scale_inplace(alpha[i]);
        outer_acc(&mut s, k.row(i), v.row(i), 1.0);
        let o = s.matvec_t(q.row(i));
        out.row_mut(i).copy_from_slice(&o);
    }
    out
}

/// Parallel (masked) form: `O = (Q K^T ⊙ M^S) V`.
pub fn parallel(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32]) -> Mat {
    let p = q.matmul_nt(k).hadamard(&SssMask::new(alpha).dense());
    p.matmul(v)
}

/// Chunkwise (SSD) form with chunk size `c`.
///
/// Per chunk: (1) intra-chunk dense masked attention, (2) inter-chunk
/// contribution `o_t += decay(start..t) · q_t^T S_in`, (3) state update
/// `S_out = decay(chunk) · S_in + Σ_s decay(s..end) k_s v_s^T`.
pub fn chunkwise(q: &Mat, k: &Mat, v: &Mat, alpha: &[f32], c: usize) -> Mat {
    assert!(c >= 1);
    let (t, dk, dv) = (q.rows, q.cols, v.cols);
    assert_eq!(alpha.len(), t);
    let mut out = Mat::zeros(t, dv);
    let mut state = Mat::zeros(dk, dv);
    let mut start = 0;
    while start < t {
        let end = (start + c).min(t);
        let len = end - start;
        // Local cumulative decay: dec_in[i] = Π_{j=start..start+i} α_j
        // (decay from chunk entry *through* position i).
        let mut dec_in = vec![0.0f32; len];
        let mut acc = 1.0f64;
        for i in 0..len {
            acc *= alpha[start + i] as f64;
            dec_in[i] = acc as f32;
        }
        let chunk_decay = dec_in[len - 1];

        // (2) inter-chunk reads.
        for i in 0..len {
            let o = state.matvec_t(q.row(start + i));
            for (dst, val) in out.row_mut(start + i).iter_mut().zip(o) {
                *dst = dec_in[i] * val;
            }
        }
        // (1) intra-chunk dense: weight(i,j) = (q_i . k_j) Π_{u=j+1..i} α_u
        //     = (q_i . k_j) * dec_in[i] / dec_in[j].
        for i in 0..len {
            let qi = q.row(start + i);
            let mut acc_row = vec![0.0f32; dv];
            for j in 0..=i {
                let w = crate::tensor::dot(qi, k.row(start + j)) * (dec_in[i] / dec_in[j]);
                for (a, &vv) in acc_row.iter_mut().zip(v.row(start + j)) {
                    *a += w * vv;
                }
            }
            for (dst, a) in out.row_mut(start + i).iter_mut().zip(acc_row) {
                *dst += a;
            }
        }
        // (3) state update.
        state.scale_inplace(chunk_decay);
        for j in 0..len {
            // decay from position j+1 .. end-1 = chunk_decay / dec_in[j]
            outer_acc(&mut state, k.row(start + j), v.row(start + j), chunk_decay / dec_in[j]);
        }
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    #[test]
    fn parallel_equals_recurrent() {
        let mut rng = Rng::new(1);
        for &t in &[1usize, 5, 33, 64] {
            let x = AttnInputs::random(t, 8, 6, &mut rng);
            assert_close(
                &parallel(&x.q, &x.k, &x.v, &x.alpha),
                &recurrent(&x.q, &x.k, &x.v, &x.alpha),
                1e-4,
                1e-4,
            );
        }
    }

    #[test]
    fn chunkwise_equals_recurrent() {
        let mut rng = Rng::new(2);
        let x = AttnInputs::random(70, 8, 6, &mut rng);
        let oracle = recurrent(&x.q, &x.k, &x.v, &x.alpha);
        for &c in &[1usize, 4, 16, 70, 128] {
            assert_close(&chunkwise(&x.q, &x.k, &x.v, &x.alpha, c), &oracle, 1e-3, 1e-3);
        }
    }

    #[test]
    fn strong_decay_forgets_distant_past() {
        // With tiny gates, output at t is dominated by the current token:
        // o_t ≈ (q_t . k_t) v_t.
        let mut rng = Rng::new(3);
        let t = 16;
        let mut x = AttnInputs::random(t, 8, 8, &mut rng);
        x.alpha = vec![1e-4; t];
        let o = recurrent(&x.q, &x.k, &x.v, &x.alpha);
        for i in 0..t {
            let w = crate::tensor::dot(x.q.row(i), x.k.row(i));
            for j in 0..8 {
                assert!((o.at(i, j) - w * x.v.at(i, j)).abs() < 1e-2);
            }
        }
    }
}
