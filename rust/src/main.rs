//! `loglinear` — the Layer-3 coordinator CLI.
//!
//! Every experiment in the paper's evaluation section is a subcommand
//! (see DESIGN.md §4 for the experiment index):
//!
//! ```text
//! loglinear info                          list artifacts
//! loglinear train        --config tiny --variant loglinear_mamba2 --steps 200
//! loglinear lm-suite     --steps 300     Table 3/6: ppl + zero-shot evals
//! loglinear per-position --steps 300     Fig. 5: per-position loss
//! loglinear mqar         --dims 16,32,64 Table 2 / Fig. 9
//! loglinear train-tasks  --steps 400     task-pretrain the `task` models
//! loglinear niah         --lens 64,128,256       Table 4 / Fig. 10
//! loglinear retrieval    --windows 64,128,256    Table 7
//! loglinear longbench                            Table 8
//! loglinear serve-demo   --requests 12   batched decode serving demo
//! ```

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use loglinear::config::RunConfig;
use loglinear::coordinator::batcher::BatchPolicy;
use loglinear::coordinator::server::DecodeServer;
use loglinear::coordinator::GenRequest;
use loglinear::data::{self, corpus, longbench, mqar, niah, retrieval};
use loglinear::eval::{self, Table};
use loglinear::info;
use loglinear::runtime::{ModelHandle, Runtime};
use loglinear::train::{self, TrainConfig};
use loglinear::util::cli::Args;
use loglinear::util::json::Json;
use loglinear::util::Rng;

fn main() {
    let args = Args::from_env();
    if let Some(level) = args.get("log") {
        loglinear::util::logger::set_level_str(level);
    }
    let cmd = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "lm-suite" => cmd_lm_suite(&args),
        "per-position" => cmd_per_position(&args),
        "mqar" => cmd_mqar(&args),
        "train-tasks" => cmd_train_tasks(&args),
        "niah" => cmd_niah(&args),
        "retrieval" => cmd_retrieval(&args),
        "longbench" => cmd_longbench(&args),
        "serve-demo" => cmd_serve_demo(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "loglinear — Log-Linear Attention reproduction (see README.md)\n\n\
         commands: info, train, lm-suite, per-position, mqar, train-tasks,\n\
         niah, retrieval, longbench, serve-demo\n\n\
         common options: --config <tiny|lm|task|mqar16..>, --variant <name>,\n\
         --variants a,b,c|all, --steps N, --lr X, --seed N, --out file.json"
    );
}

fn variants_from(args: &Args, default: &[&str]) -> Vec<String> {
    let vs = args.str_list_or("variants", default);
    if vs.len() == 1 && vs[0] == "all" {
        ["transformer", "mamba2", "loglinear_mamba2", "gdn", "loglinear_gdn"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        vs
    }
}

fn write_json(path: &Option<PathBuf>, j: &Json) -> Result<()> {
    if let Some(p) = path {
        std::fs::write(p, j.pretty())?;
        info!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let dir = &cfg.artifacts;
    println!("artifacts dir: {}", dir.display());
    let mut found = 0;
    for entry in std::fs::read_dir(dir)? {
        let name = entry?.file_name().to_string_lossy().to_string();
        if let Some(stem) = name.strip_prefix("manifest_").and_then(|s| s.strip_suffix(".json")) {
            let m = loglinear::runtime::Manifest::load(dir, stem)?;
            println!(
                "  {stem}: variant={} params={} batch={} seq={} artifacts={}",
                m.variant,
                m.param_count,
                m.batch,
                m.cfg("seq_len"),
                m.artifact_paths.len()
            );
            found += 1;
        }
    }
    if found == 0 {
        println!("  (none — run `make artifacts`)");
    }
    Ok(())
}

fn load_model(rt: &Runtime, cfg: &RunConfig) -> Result<ModelHandle> {
    ModelHandle::load(rt, &cfg.artifacts, &cfg.model_name())
        .map_err(|e| anyhow!("loading {} (run `make artifacts`?): {e}", cfg.model_name()))
}

fn default_corpus(model: &ModelHandle, seed: u64) -> corpus::Corpus {
    let seq = model.manifest.cfg("seq_len");
    corpus::Corpus::new(
        corpus::CorpusConfig {
            vocab: model.manifest.cfg("vocab"),
            seq,
            recall_band: (8, seq * 3 / 4),
            ..Default::default()
        },
        seed,
    )
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let rt = Runtime::cpu()?;
    let mut model = load_model(&rt, &cfg)?;
    info!("training {} ({} params)", cfg.model_name(), model.manifest.param_count);
    let corpus = default_corpus(&model, 1000);
    let tc = TrainConfig {
        steps: cfg.steps,
        lr: cfg.lr,
        warmup: cfg.warmup,
        seed: cfg.seed,
        checkpoint: Some(cfg.artifacts.join(format!("ckpt_{}.bin", cfg.model_name()))),
        ..Default::default()
    };
    let curve = train::train(&rt, &mut model, &corpus, &tc)?;
    let j = Json::Arr(
        curve
            .iter()
            .map(|(s, l, sm)| Json::obj().set("step", *s).set("loss", *l).set("ema", *sm))
            .collect(),
    );
    write_json(&cfg.out, &j)?;
    Ok(())
}

/// Train (or reuse checkpoint) + evaluate one variant on the LM suite.
fn lm_eval_one(rt: &Runtime, cfg: &RunConfig, variant: &str) -> Result<(f64, f64, f64, f64)> {
    let mut vcfg = cfg.clone();
    vcfg.variant = variant.to_string();
    let mut model = load_model(rt, &vcfg)?;
    let ckpt = cfg.artifacts.join(format!("ckpt_{}.bin", vcfg.model_name()));
    let corpus = default_corpus(&model, 1000);
    if ckpt.exists() {
        model.load_checkpoint(&ckpt)?;
        info!("{variant}: loaded checkpoint");
    } else {
        info!("{variant}: training {} steps", cfg.steps);
        let tc = TrainConfig {
            steps: cfg.steps,
            lr: cfg.lr,
            warmup: cfg.warmup,
            seed: cfg.seed,
            checkpoint: Some(ckpt),
            ..Default::default()
        };
        train::train(rt, &mut model, &corpus, &tc)?;
    }
    // held-out ppl (eval seeds disjoint from the training stream)
    let batch = model.manifest.batch;
    let mut eval_rng = Rng::new(777_000);
    let (loss, ppl) = eval::perplexity(
        &model,
        || corpus.train_batch(batch, &mut eval_rng),
        cfg.eval_batches,
    )?;
    // LAMBADA-style cloze accuracy
    let mut rng2 = Rng::new(778_000);
    let lamb =
        eval::task_accuracy_n(&model, || corpus.lambada_batch(batch, &mut rng2), cfg.eval_batches)?;
    // planted-fact recall accuracy (the zero-shot analogue)
    let mut rng3 = Rng::new(779_000);
    let recall =
        eval::task_accuracy_n(&model, || corpus.eval_batch(batch, &mut rng3), cfg.eval_batches)?;
    Ok((loss, ppl, lamb, recall))
}

fn cmd_lm_suite(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let rt = Runtime::cpu()?;
    let variants = variants_from(args, &["all"]);
    let mut table = Table::new(&["model", "loss", "ppl", "lambada-acc", "recall-acc"]);
    let mut rows = Vec::new();
    for v in &variants {
        let (loss, ppl, lamb, recall) = lm_eval_one(&rt, &cfg, v)?;
        table.row(vec![
            v.clone(),
            format!("{loss:.4}"),
            format!("{ppl:.2}"),
            format!("{lamb:.3}"),
            format!("{recall:.3}"),
        ]);
        rows.push(
            Json::obj()
                .set("model", v.as_str())
                .set("loss", loss)
                .set("ppl", ppl)
                .set("lambada", lamb)
                .set("recall", recall),
        );
    }
    println!("\nTable 3/6 analogue — LM suite ({} config):", cfg.config);
    table.print();
    write_json(&cfg.out, &Json::Arr(rows))?;
    Ok(())
}

fn cmd_per_position(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let rt = Runtime::cpu()?;
    let variants = variants_from(args, &["all"]);
    let window = args.usize_or("window", 11);
    let mut out = Json::obj();
    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    for v in &variants {
        let mut vcfg = cfg.clone();
        vcfg.variant = v.clone();
        let mut model = load_model(&rt, &vcfg)?;
        let ckpt = cfg.artifacts.join(format!("ckpt_{}.bin", vcfg.model_name()));
        if ckpt.exists() {
            model.load_checkpoint(&ckpt)?;
        } else {
            anyhow::bail!("no checkpoint for {v}; run lm-suite first");
        }
        let corpus = default_corpus(&model, 1000);
        let batch = model.manifest.batch;
        let mut rng = Rng::new(888_000);
        let curve = eval::per_position_loss(
            &model,
            || corpus.train_batch(batch, &mut rng),
            cfg.eval_batches,
        )?;
        let smoothed = loglinear::util::stats::running_average(&curve, window);
        out = out.set(
            v.as_str(),
            smoothed.iter().map(|&x| Json::Num(x)).collect::<Vec<_>>(),
        );
        curves.push((v.clone(), smoothed));
    }
    // quartile summary table (Fig. 5 analogue, printable)
    let mut table = Table::new(&["model", "loss@Q1", "loss@Q2", "loss@Q3", "loss@end", "slope"]);
    for (v, c) in &curves {
        let n = c.len();
        let xs: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let (_a, b, _r2) = loglinear::util::stats::ols(&xs, c);
        table.row(vec![
            v.clone(),
            format!("{:.4}", c[n / 4]),
            format!("{:.4}", c[n / 2]),
            format!("{:.4}", c[3 * n / 4]),
            format!("{:.4}", c[n - 1]),
            format!("{:+.2e}", b),
        ]);
    }
    println!("\nFig. 5 analogue — per-position loss (more negative slope = better long-context use):");
    table.print();
    write_json(&cfg.out, &out)?;
    Ok(())
}

fn cmd_mqar(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let rt = Runtime::cpu()?;
    let dims = args.usize_list_or("dims", &[16, 32, 64]);
    let variants = variants_from(args, &["mamba2", "loglinear_mamba2", "gdn", "loglinear_gdn"]);
    let seeds = args.usize_or("seeds", 2);
    let max_steps = args.usize_or("max-steps", cfg.steps.max(300));
    let n_pairs = args.usize_or("pairs", 16);
    let mut table = Table::new(&["model", "dim", "acc-mean", "acc-std", "steps-to-99"]);
    let mut rows = Vec::new();
    for dim in &dims {
        for v in &variants {
            let mut accs = Vec::new();
            let mut stop_steps = Vec::new();
            for seed in 0..seeds {
                let mut vcfg = cfg.clone();
                vcfg.config = format!("mqar{dim}");
                vcfg.variant = v.clone();
                let mut model = load_model(&rt, &vcfg)?;
                model.ensure_train(&rt)?;
                let batch = model.manifest.batch;
                let mcfg = mqar::MqarConfig { n_pairs, ..Default::default() };
                let mut rng = Rng::new(42 + seed as u64);
                let mut eval_rng = Rng::new(999_000 + seed as u64);
                // train with early stopping at 99% eval accuracy (App. D)
                let mut acc = 0.0;
                let mut stopped_at = max_steps;
                for step in 1..=max_steps {
                    let tb = mqar::generate(&mcfg, batch, &mut rng);
                    let lr = train::lr_schedule(step - 1, max_steps, cfg.lr, cfg.warmup) as f32;
                    model.train_step(step as i32, &tb.tokens, lr)?;
                    if step % 25 == 0 || step == max_steps {
                        acc = eval::task_accuracy_n(
                            &model,
                            || mqar::generate(&mcfg, batch, &mut eval_rng),
                            4,
                        )?;
                        if acc >= 0.99 {
                            stopped_at = step;
                            break;
                        }
                    }
                }
                accs.push(acc);
                stop_steps.push(stopped_at);
                info!("mqar d={dim} {v} seed={seed}: acc={acc:.3} steps={stopped_at}");
            }
            let mean = accs.iter().sum::<f64>() / accs.len() as f64;
            let std = (accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>()
                / accs.len() as f64)
                .sqrt();
            table.row(vec![
                v.clone(),
                dim.to_string(),
                format!("{:.1}", mean * 100.0),
                format!("{:.1}", std * 100.0),
                format!("{}", stop_steps.iter().sum::<usize>() / stop_steps.len()),
            ]);
            rows.push(
                Json::obj()
                    .set("model", v.as_str())
                    .set("dim", *dim)
                    .set("acc", mean)
                    .set("std", std),
            );
        }
    }
    println!("\nTable 2 analogue — MQAR accuracy (%):");
    table.print();
    write_json(&cfg.out, &Json::Arr(rows))?;
    Ok(())
}

fn task_ckpt(cfg: &RunConfig, variant: &str) -> PathBuf {
    cfg.artifacts.join(format!("ckpt_task_{variant}.bin"))
}

fn load_task_model(rt: &Runtime, cfg: &RunConfig, variant: &str) -> Result<ModelHandle> {
    let mut vcfg = cfg.clone();
    vcfg.config = "task".into();
    vcfg.variant = variant.to_string();
    let mut model = load_model(rt, &vcfg)?;
    let ckpt = task_ckpt(cfg, variant);
    if ckpt.exists() {
        model.load_checkpoint(&ckpt)?;
    } else {
        anyhow::bail!("no task checkpoint for {variant}; run `loglinear train-tasks` first");
    }
    Ok(model)
}

fn cmd_train_tasks(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let rt = Runtime::cpu()?;
    let variants = variants_from(args, &["all"]);
    for v in &variants {
        let mut vcfg = cfg.clone();
        vcfg.config = "task".into();
        vcfg.variant = v.clone();
        let mut model = load_model(&rt, &vcfg)?;
        model.ensure_train(&rt)?;
        let batch = model.manifest.batch;
        let seq = model.manifest.cfg("seq_len");
        let vocab = model.manifest.cfg("vocab");
        let mut rng = Rng::new(cfg.seed);
        info!("task-training {v} for {} steps", cfg.steps);
        for step in 1..=cfg.steps {
            let tokens = data::mixture_batch(batch, seq, vocab, &mut rng);
            let lr = train::lr_schedule(step - 1, cfg.steps, cfg.lr, cfg.warmup) as f32;
            let out = model.train_step(step as i32, &tokens, lr)?;
            if step % 25 == 0 || step == 1 {
                info!("  {v} step {step}: loss {:.4}", out.loss);
            }
        }
        model.save_checkpoint(&task_ckpt(&cfg, v))?;
    }
    Ok(())
}

fn cmd_niah(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let rt = Runtime::cpu()?;
    let variants = variants_from(args, &["mamba2", "loglinear_mamba2", "gdn", "loglinear_gdn"]);
    let lens = args.usize_list_or("lens", &[64, 128, 256]);
    let headers: Vec<String> = ["task", "model"]
        .iter()
        .map(|s| s.to_string())
        .chain(lens.iter().map(|l| format!("T={l}")))
        .collect();
    let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut rows = Vec::new();
    for &task in niah::NiahTask::all() {
        for v in &variants {
            let mut model = load_task_model(&rt, &cfg, v)?;
            let mut cells = vec![task.name().to_string(), v.clone()];
            for &len in &lens {
                model.ensure_eval_seq(&rt, len)?;
                let vocab = model.manifest.cfg("vocab");
                let batch = model.manifest.batch;
                let ncfg = niah::NiahConfig { seq: len, vocab };
                let mut rng = Rng::new(123_400 + len as u64);
                let mut acc = 0.0;
                for _ in 0..cfg.eval_batches {
                    let tb = niah::generate(task, &ncfg, batch, &mut rng);
                    let out = model.eval_at(len, &tb.tokens)?;
                    acc += tb.accuracy(&out.preds);
                }
                acc /= cfg.eval_batches as f64;
                cells.push(format!("{:.1}", acc * 100.0));
                rows.push(
                    Json::obj()
                        .set("task", task.name())
                        .set("model", v.as_str())
                        .set("len", len)
                        .set("acc", acc),
                );
            }
            table.row(cells);
        }
    }
    println!("\nTable 4 analogue — NIAH accuracy (%):");
    table.print();
    write_json(&cfg.out, &Json::Arr(rows))?;
    Ok(())
}

fn cmd_retrieval(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let rt = Runtime::cpu()?;
    let variants = variants_from(args, &["mamba2", "loglinear_mamba2", "gdn", "loglinear_gdn"]);
    let windows = args.usize_list_or("windows", &[64, 128, 256]);
    let headers: Vec<String> = ["task", "model"]
        .iter()
        .map(|s| s.to_string())
        .chain(windows.iter().map(|w| format!("W={w}")))
        .collect();
    let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut rows = Vec::new();
    for &task in retrieval::RetrievalTask::all() {
        for v in &variants {
            let mut model = load_task_model(&rt, &cfg, v)?;
            let mut cells = vec![task.name().to_string(), v.clone()];
            for &w in &windows {
                model.ensure_eval_seq(&rt, w)?;
                let vocab = model.manifest.cfg("vocab");
                let batch = model.manifest.batch;
                let rcfg = retrieval::RetrievalConfig {
                    doc_len: model.manifest.cfg("seq_len"),
                    window: w,
                    vocab,
                };
                let mut rng = Rng::new(500_000 + w as u64);
                let mut acc = 0.0;
                for _ in 0..cfg.eval_batches {
                    let tb = retrieval::generate(task, &rcfg, batch, &mut rng);
                    let out = model.eval_at(w, &tb.tokens)?;
                    acc += tb.accuracy(&out.preds);
                }
                acc /= cfg.eval_batches as f64;
                cells.push(format!("{:.1}", acc * 100.0));
                rows.push(
                    Json::obj()
                        .set("task", task.name())
                        .set("model", v.as_str())
                        .set("window", w)
                        .set("acc", acc),
                );
            }
            table.row(cells);
        }
    }
    println!("\nTable 7 analogue — retrieval accuracy (%) vs truncation window:");
    table.print();
    write_json(&cfg.out, &Json::Arr(rows))?;
    Ok(())
}

fn cmd_longbench(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let rt = Runtime::cpu()?;
    let variants = variants_from(args, &["mamba2", "loglinear_mamba2", "gdn", "loglinear_gdn"]);
    let headers: Vec<String> = std::iter::once("model".to_string())
        .chain(longbench::LongBenchTask::all().iter().map(|t| t.name().to_string()))
        .collect();
    let mut table = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut rows = Vec::new();
    for v in &variants {
        let model = load_task_model(&rt, &cfg, v)?;
        let vocab = model.manifest.cfg("vocab");
        let seq = model.manifest.cfg("seq_len");
        let batch = model.manifest.batch;
        let mut cells = vec![v.clone()];
        for &task in longbench::LongBenchTask::all() {
            let lcfg = longbench::LongBenchConfig { seq, vocab };
            let mut rng = Rng::new(600_000);
            let acc = eval::task_accuracy_n(
                &model,
                || longbench::generate(task, &lcfg, batch, &mut rng),
                cfg.eval_batches,
            )?;
            cells.push(format!("{:.1}", acc * 100.0));
            rows.push(
                Json::obj()
                    .set("task", task.name())
                    .set("model", v.as_str())
                    .set("acc", acc),
            );
        }
        table.row(cells);
    }
    println!("\nTable 8 analogue — LongBench-style accuracy (%):");
    table.print();
    write_json(&cfg.out, &Json::Arr(rows))?;
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let rt = Runtime::cpu()?;
    let model = load_model(&rt, &cfg)?;
    let n_requests = args.usize_or("requests", 12);
    let max_new = args.usize_or("max-new", 24);
    let policy = BatchPolicy::new(
        model.decode_batches_available(),
        std::time::Duration::from_millis(2),
    );
    let mut server = DecodeServer::new(&rt, model, policy)?;
    let mut rng = Rng::new(7);
    let vocab = server.model().manifest.cfg("vocab");
    for id in 0..n_requests as u64 {
        let plen = rng.range(4, 16);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        server.submit(GenRequest { id, prompt, max_new })?;
    }
    let t0 = std::time::Instant::now();
    let results = server.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats.clone();
    println!("served {} requests in {:.2}s", results.len(), wall);
    println!(
        "decode steps: {}  tokens: {}  throughput: {:.0} tok/s",
        stats.steps,
        stats.tokens_processed,
        stats.tokens_per_second()
    );
    if let Some(s) = stats.latency_summary() {
        println!(
            "step latency: mean {:.2}ms p50 {:.2}ms p99 {:.2}ms",
            s.mean * 1e3,
            s.p50 * 1e3,
            s.p99 * 1e3
        );
    }
    println!(
        "mean batch occupancy: {:.2}  peak state bytes: {}",
        stats.mean_occupancy(),
        stats.peak_state_bytes
    );
    for r in results.iter().take(3) {
        println!("  req {}: {} tokens, latency {:.2}s", r.id, r.tokens.len(), r.latency);
    }
    Ok(())
}
