//! 1-semiseparable (SSS) masks from scalar gates (paper Eq. 2).
//!
//! `M^S[i][j] = Π_{k=j+1}^i α_k` for `i >= j`, 0 otherwise. This is the
//! Mamba-2 / RetNet temporal structure: every lower-triangular submatrix
//! has rank ≤ 1, which is what makes the O(T) chunkwise algorithm work.

use crate::tensor::Mat;

/// A 1-semiseparable causal mask defined by per-step gates `α_t ∈ (0, 1]`.
#[derive(Debug, Clone)]
pub struct SssMask {
    /// `log α_t` per step (logs for numerical stability over long T).
    pub log_alpha: Vec<f64>,
}

impl SssMask {
    pub fn new(alphas: &[f32]) -> SssMask {
        assert!(
            alphas.iter().all(|&a| a > 0.0),
            "gates must be positive for log-space cumsum"
        );
        SssMask {
            log_alpha: alphas.iter().map(|&a| (a as f64).ln()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.log_alpha.len()
    }

    pub fn is_empty(&self) -> bool {
        self.log_alpha.is_empty()
    }

    /// `M[i][j] = Π_{k=j+1}^i α_k` via segment-sum of logs (the `segsum`
    /// of the paper's reference code).
    pub fn entry(&self, i: usize, j: usize) -> f32 {
        if j > i {
            return 0.0;
        }
        let s: f64 = self.log_alpha[j + 1..=i].iter().sum();
        s.exp() as f32
    }

    /// Materialize the dense `T x T` mask.
    pub fn dense(&self) -> Mat {
        let t = self.len();
        // Cumulative log sums: cum[i] = sum of log_alpha[0..=i-1]
        let mut cum = vec![0.0f64; t + 1];
        for i in 0..t {
            cum[i + 1] = cum[i] + self.log_alpha[i];
        }
        Mat::from_fn(t, t, |i, j| {
            if j > i {
                0.0
            } else {
                (cum[i + 1] - cum[j + 1]).exp() as f32
            }
        })
    }

    /// O(T) masked matvec: `y = M^S x` via the linear recurrence
    /// `y_i = α_i y_{i-1} + x_i` — the reason SSS masks give O(T) training
    /// and O(1)-state decoding.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.len());
        let mut y = Vec::with_capacity(x.len());
        let mut carry = 0.0f64;
        for (i, &xi) in x.iter().enumerate() {
            let a = self.log_alpha[i].exp();
            // y_i = x_i + α_i * y_{i-1}, but note M[i][i] = 1 (empty product)
            carry = xi as f64 + a * carry * if i == 0 { 0.0 } else { 1.0 };
            if i == 0 {
                carry = xi as f64;
            }
            y.push(carry as f32);
        }
        y
    }
}

/// Stable segment-sum helper: given per-step values `a`, return the matrix
/// `S[i][j] = Σ_{k=j+1}^i a_k` (lower triangle; `-inf` above). Mirrors the
/// `segsum` in the paper's Appendix C and in `python/compile/kernels/`.
pub fn segsum(a: &[f32]) -> Mat {
    let t = a.len();
    let mut cum = vec![0.0f64; t + 1];
    for i in 0..t {
        cum[i + 1] = cum[i] + a[i] as f64;
    }
    Mat::from_fn(t, t, |i, j| {
        if j > i {
            f32::NEG_INFINITY
        } else {
            (cum[i + 1] - cum[j + 1]) as f32
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_gates(t: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..t).map(|_| rng.range_f32(0.7, 1.0)).collect()
    }

    #[test]
    fn entry_matches_naive_product() {
        let alphas = random_gates(16, 1);
        let m = SssMask::new(&alphas);
        for i in 0..16 {
            for j in 0..=i {
                let naive: f32 = alphas[j + 1..=i].iter().product();
                assert!((m.entry(i, j) - naive).abs() < 1e-5, "({i},{j})");
            }
        }
    }

    #[test]
    fn dense_agrees_with_entry() {
        let alphas = random_gates(32, 2);
        let m = SssMask::new(&alphas);
        let d = m.dense();
        for i in 0..32 {
            for j in 0..32 {
                assert!((d.at(i, j) - m.entry(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn diagonal_is_one_strict_upper_zero() {
        let m = SssMask::new(&random_gates(8, 3)).dense();
        for i in 0..8 {
            assert!((m.at(i, i) - 1.0).abs() < 1e-6);
            for j in i + 1..8 {
                assert_eq!(m.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn matvec_recurrence_matches_dense() {
        let alphas = random_gates(64, 4);
        let m = SssMask::new(&alphas);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..64).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let fast = m.matvec(&x);
        let slow = m.dense().matvec(&x);
        for i in 0..64 {
            assert!((fast[i] - slow[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn semiseparable_rank_one_submatrices() {
        // Every 2x2 strictly-lower submatrix [[a,b],[c,d]] of an SSS mask
        // satisfies a*d == b*c (rank 1).
        let alphas = random_gates(24, 6);
        let d = SssMask::new(&alphas).dense();
        for i1 in 1..24 {
            for i2 in i1 + 1..24 {
                for j1 in 0..i1 {
                    for j2 in j1 + 1..i1 {
                        let (a, b) = (d.at(i1, j1) as f64, d.at(i1, j2) as f64);
                        let (c, e) = (d.at(i2, j1) as f64, d.at(i2, j2) as f64);
                        assert!(
                            (a * e - b * c).abs() < 1e-4 * (a * e).abs().max(1e-8),
                            "rank>1 at ({i1},{i2})x({j1},{j2})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn segsum_matches_exp_relation() {
        let alphas = random_gates(12, 7);
        let logs: Vec<f32> = alphas.iter().map(|a| a.ln()).collect();
        let s = segsum(&logs);
        let m = SssMask::new(&alphas);
        for i in 0..12 {
            for j in 0..12 {
                if j > i {
                    assert_eq!(s.at(i, j), f32::NEG_INFINITY);
                } else {
                    assert!((s.at(i, j).exp() - m.entry(i, j)).abs() < 1e-5);
                }
            }
        }
    }
}
