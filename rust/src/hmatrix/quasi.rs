//! Quasi-hierarchical masks `M = M^H ⊙ M^S` (paper Eq. 4 + App. B.3).
//!
//! `M[t][s] = λ_t^(ℓ(t,s)) · Π_{k=s+1}^t α_k` for `s <= t`. One basis
//! sequence (the column one, from the gate cumprods) nests across levels
//! — that is what makes the matrix *quasi*-hierarchical and yields the
//! `O(log T)` decoding recurrence; the row weights `λ_t^(ℓ)` are free per
//! level, which is what makes it strictly more expressive than a
//! semiseparable mask.
//!
//! [`QuasiH::matvec`] is the `O(T log T)` structured multiply, built on a
//! dyadic merge of block summaries (numerically safe: all intermediate
//! quantities are products of gates `α ≤ 1`, so they underflow benignly
//! instead of overflowing).

use crate::fenwick;
use crate::tensor::Mat;

/// A quasi-hierarchical mask defined by per-step gates and per-(step,level)
/// weights λ. Borrows its inputs — constructing one (e.g. per training
/// step in `parallel_from_a`) copies nothing.
#[derive(Debug, Clone, Copy)]
pub struct QuasiH<'a> {
    /// gates `α_t ∈ (0, 1]`, length T.
    pub alpha: &'a [f32],
    /// λ, shape (T, num_levels(T)) row-major.
    pub lambda: &'a Mat,
}

impl<'a> QuasiH<'a> {
    pub fn new(alpha: &'a [f32], lambda: &'a Mat) -> QuasiH<'a> {
        assert_eq!(alpha.len(), lambda.rows);
        assert!(
            alpha.iter().all(|&a| a > 0.0 && a <= 1.0),
            "gates must be in (0, 1]"
        );
        assert!(lambda.cols >= fenwick::num_levels(alpha.len().max(1)));
        QuasiH { alpha, lambda }
    }

    pub fn len(&self) -> usize {
        self.alpha.len()
    }

    pub fn is_empty(&self) -> bool {
        self.alpha.is_empty()
    }

    /// Entry `M[t][s]` (slow; for tests and dense materialization).
    pub fn entry(&self, t: usize, s: usize) -> f32 {
        if s > t {
            return 0.0;
        }
        let l = fenwick::level_of(t, s);
        let decay: f64 = self.alpha[s + 1..=t].iter().map(|&a| a as f64).fold(1.0, |p, a| p * a);
        self.lambda.at(t, l) * decay as f32
    }

    /// Dense materialization (tests / small T).
    pub fn dense(&self) -> Mat {
        let t = self.len();
        // log-cumsum of gates for O(T^2) total instead of O(T^3)
        let mut cum = vec![0.0f64; t + 1];
        for i in 0..t {
            cum[i + 1] = cum[i] + (self.alpha[i] as f64).ln();
        }
        Mat::from_fn(t, t, |i, j| {
            if j > i {
                0.0
            } else {
                let l = fenwick::level_of(i, j);
                self.lambda.at(i, l) * (cum[i + 1] - cum[j + 1]).exp() as f32
            }
        })
    }

    /// `y = M x` in `O(T log T)` using dyadic block summaries.
    ///
    /// For each level ℓ ≥ 1, aligned blocks `B` of size `2^(ℓ-1)` carry
    /// `Z_B = Σ_{s∈B} (Π_{k=s+1}^{max B} α_k) x_s`, merged bottom-up via
    /// `Z_parent = Z_right + D_right · Z_left`, `D_parent = D_left·D_right`
    /// with `D_B = Π_{k∈B} α_k`. The bucket of level ℓ for query `t`
    /// contributes `λ_t^(ℓ) · (Π_{k=maxB+1}^t α_k) · Z_B`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let t_len = self.len();
        assert_eq!(x.len(), t_len);
        if t_len == 0 {
            return Vec::new();
        }
        let nl = fenwick::num_levels(t_len);

        // logcum[i] = sum of ln(alpha[0..i]) for the cross-bucket decay.
        let mut logcum = vec![0.0f64; t_len + 1];
        for i in 0..t_len {
            logcum[i + 1] = logcum[i] + (self.alpha[i] as f64).ln();
        }

        // Level-1 blocks: single elements.
        let mut z: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let mut d: Vec<f64> = self.alpha.iter().map(|&a| a as f64).collect();

        let mut y: Vec<f64> = vec![0.0; t_len];
        // Sentinel level 0: y_t += λ_t^(0) x_t.
        for t in 0..t_len {
            y[t] += self.lambda.at(t, 0) as f64 * x[t] as f64;
        }

        for level in 1..nl {
            let bsize = 1usize << (level - 1);
            // Bucket at this level exists for t with bit (level-1) set:
            // B = [m - bsize, m) with m = t with low (level-1) bits cleared.
            for t in 0..t_len {
                if (t >> (level - 1)) & 1 == 1 {
                    let m = t & !(bsize - 1); // end (exclusive) of bucket
                    let block_idx = (m - bsize) / bsize;
                    // decay from maxB = m-1 to t: Π_{k=m}^{t} α_k
                    let decay = (logcum[t + 1] - logcum[m]).exp();
                    y[t] += self.lambda.at(t, level) as f64 * decay * z[block_idx];
                }
            }
            // Merge blocks pairwise for the next level.
            let nblocks = z.len() / 2;
            let mut z2 = Vec::with_capacity(nblocks);
            let mut d2 = Vec::with_capacity(nblocks);
            for b in 0..nblocks {
                let (zl, zr) = (z[2 * b], z[2 * b + 1]);
                let (dl, dr) = (d[2 * b], d[2 * b + 1]);
                z2.push(zr + dr * zl);
                d2.push(dl * dr);
            }
            z = z2;
            d = d2;
        }
        y.into_iter().map(|v| v as f32).collect()
    }

    /// Storage in floats: T gates + T·L lambdas = `O(T log T)`.
    pub fn storage_floats(&self) -> usize {
        self.alpha.len() + self.lambda.rows * self.lambda.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Random (alpha, lambda) inputs; `QuasiH::new(&a, &l)` borrows them.
    fn random_inputs(t: usize, seed: u64) -> (Vec<f32>, Mat) {
        let mut rng = Rng::new(seed);
        let alpha: Vec<f32> = (0..t).map(|_| rng.range_f32(0.8, 1.0)).collect();
        let nl = fenwick::num_levels(t);
        let lambda = Mat::rand_uniform(t, nl, 0.0, 1.0, &mut rng);
        (alpha, lambda)
    }

    #[test]
    fn dense_agrees_with_entry() {
        let (alpha, lambda) = random_inputs(32, 1);
        let q = QuasiH::new(&alpha, &lambda);
        let d = q.dense();
        for i in 0..32 {
            for j in 0..32 {
                assert!((d.at(i, j) - q.entry(i, j)).abs() < 1e-5, "({i},{j})");
            }
        }
    }

    #[test]
    fn fast_matvec_matches_dense() {
        for &t in &[1usize, 2, 3, 7, 8, 16, 33, 64, 100, 128] {
            let (alpha, lambda) = random_inputs(t, t as u64);
            let q = QuasiH::new(&alpha, &lambda);
            let mut rng = Rng::new(99 + t as u64);
            let x: Vec<f32> = (0..t).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let fast = q.matvec(&x);
            let slow = q.dense().matvec(&x);
            for i in 0..t {
                assert!(
                    (fast[i] - slow[i]).abs() < 1e-3,
                    "T={t} i={i}: {} vs {}",
                    fast[i],
                    slow[i]
                );
            }
        }
    }

    #[test]
    fn collapses_to_sss_when_lambda_constant() {
        // Paper §3.1: if all λ_t^(ℓ) are equal the model collapses to
        // (gated) linear attention, i.e. M == M^S.
        let t = 64;
        let mut rng = Rng::new(5);
        let alpha: Vec<f32> = (0..t).map(|_| rng.range_f32(0.8, 1.0)).collect();
        let lambda = Mat::from_fn(t, fenwick::num_levels(t), |_, _| 1.0);
        let q = QuasiH::new(&alpha, &lambda);
        let sss = crate::hmatrix::sss::SssMask::new(&alpha);
        crate::tensor::assert_close(&q.dense(), &sss.dense(), 1e-4, 1e-4);
    }

    #[test]
    fn ungated_is_pure_hmask() {
        // α = 1 everywhere: the mask degenerates to the pure M^H of Eq. 4.
        let t = 16;
        let mut rng = Rng::new(6);
        let lambda = Mat::rand_uniform(t, fenwick::num_levels(t), 0.0, 1.0, &mut rng);
        let ones = vec![1.0f32; t];
        let q = QuasiH::new(&ones, &lambda);
        let m = fenwick::hmask(&lambda, t);
        crate::tensor::assert_close(&q.dense(), &m, 1e-6, 0.0);
    }

    #[test]
    fn storage_is_t_log_t() {
        let (alpha, lambda) = random_inputs(1024, 7);
        let q = QuasiH::new(&alpha, &lambda);
        assert_eq!(
            q.storage_floats(),
            1024 + 1024 * fenwick::num_levels(1024)
        );
        assert!(q.storage_floats() < 1024 * 1024 / 8);
    }

    #[test]
    fn no_overflow_with_strong_decay_long_t() {
        // Strong decay + long T used to overflow naive exp(-cumsum)
        // prefix-sum formulations; the dyadic merge must stay finite.
        let t = 4096;
        let alpha = vec![0.5f32; t];
        let lambda = Mat::from_fn(t, fenwick::num_levels(t), |_, _| 1.0);
        let q = QuasiH::new(&alpha, &lambda);
        let x = vec![1.0f32; t];
        let y = q.matvec(&x);
        assert!(y.iter().all(|v| v.is_finite()));
        // y_t -> 2.0 geometric limit
        assert!((y[t - 1] - 2.0).abs() < 1e-3);
    }
}
