//! Structured masking matrices (paper §2 and Appendix B).
//!
//! The paper's unified view is `O = (A ⊙ M) V`, where the *structure* of
//! the causal mask `M` determines training/inference complexity:
//!
//! | structure | example | train | decode memory |
//! |-----------|---------|-------|---------------|
//! | all-ones lower triangle | linear attention | O(T) | O(1) |
//! | 1-semiseparable ([`sss`]) | RetNet / Mamba-2 | O(T) | O(1) |
//! | quasi-hierarchical ([`quasi`]) | **log-linear attention** | O(T log T) | O(log T) |
//! | HODLR ([`hodlr`]) | general H-matrix | O(T log T) | (no known O(log T) recurrence) |
//!
//! [`quasi::QuasiH`] is the paper's `M^H ⊙ M^S` object; its `matvec` is the
//! O(T log T) structured multiply that the chunkwise training algorithm
//! exploits, and `hodlr::Hodlr` exists both as the general class it embeds
//! into and as the weak-vs-strong admissibility ablation target (App. B.4).

pub mod sss;
pub mod hodlr;
pub mod quasi;

pub use quasi::QuasiH;
pub use sss::SssMask;
