//! HODLR (Hierarchically Off-Diagonal Low-Rank) matrices (App. B.1) with
//! weak **and** strong admissibility variants (App. B.4).
//!
//! A balanced binary cluster tree over `{0..n}` partitions the matrix; at
//! every level the admissible off-diagonal blocks are stored in factored
//! low-rank form `U Σ V^T`. `matvec` then costs `O(k n log n)`. We use
//! these as (a) the general class the paper's `M^H` embeds into, and
//! (b) the ablation of App. B.4: strong admissibility refines the
//! partition (only well-separated blocks are compressed), trading a
//! constant-factor more work for finer structure — the paper measured
//! ~4x slowdown for marginal accuracy gain and chose weak admissibility.

use crate::tensor::Mat;

/// One admissible (compressed) block: `rows x cols` sub-block starting at
/// `(r0, c0)`, stored as `u @ v^T` with `u: rows x k`, `v: cols x k`.
#[derive(Debug, Clone)]
pub struct LowRankBlock {
    pub r0: usize,
    pub c0: usize,
    pub u: Mat,
    pub v: Mat,
}

/// A dense (inadmissible) block at `(r0, c0)`.
#[derive(Debug, Clone)]
pub struct DenseBlock {
    pub r0: usize,
    pub c0: usize,
    pub m: Mat,
}

/// Admissibility criterion for the cluster-tree partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admissibility {
    /// Weak (HODLR): every off-diagonal sibling block is admissible.
    Weak,
    /// Strong: a block is admissible only if the clusters are separated by
    /// at least one cluster width at that level; near-diagonal neighbours
    /// recurse further (finer partition, more blocks).
    Strong,
}

/// A hierarchical matrix: a set of low-rank blocks plus dense leaf blocks.
#[derive(Debug, Clone)]
pub struct Hodlr {
    pub n: usize,
    pub leaf_size: usize,
    pub admissibility: Admissibility,
    pub low_rank: Vec<LowRankBlock>,
    pub dense: Vec<DenseBlock>,
}

impl Hodlr {
    /// Build from a dense matrix, compressing admissible blocks at the
    /// given rank via a few rounds of orthogonal iteration. `n` must be a
    /// power of two and `leaf_size | n`.
    pub fn from_dense(a: &Mat, leaf_size: usize, rank: usize, adm: Admissibility) -> Hodlr {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        assert!(n.is_power_of_two(), "HODLR needs power-of-two n");
        assert!(leaf_size.is_power_of_two() && leaf_size <= n);
        let mut h = Hodlr {
            n,
            leaf_size,
            admissibility: adm,
            low_rank: Vec::new(),
            dense: Vec::new(),
        };
        h.build(a, 0, 0, n, rank);
        h
    }

    fn build(&mut self, a: &Mat, r0: usize, c0: usize, size: usize, rank: usize) {
        if size <= self.leaf_size {
            self.dense.push(DenseBlock {
                r0,
                c0,
                m: submat(a, r0, c0, size, size),
            });
            return;
        }
        let half = size / 2;
        // Diagonal children always recurse.
        self.build(a, r0, c0, half, rank);
        self.build(a, r0 + half, c0 + half, half, rank);
        // Off-diagonal children: admissible -> compress; else recurse/dense.
        match self.admissibility {
            Admissibility::Weak => {
                self.compress(a, r0, c0 + half, half, rank);
                self.compress(a, r0 + half, c0, half, rank);
            }
            Admissibility::Strong => {
                // Neighbouring blocks (distance 0 at this level) are NOT
                // admissible: split them further. At leaf size store dense.
                self.build_strong_offdiag(a, r0, c0 + half, half, rank);
                self.build_strong_offdiag(a, r0 + half, c0, half, rank);
            }
        }
    }

    /// Strong admissibility: recurse on a near-diagonal off-diagonal block.
    /// Its children that become well-separated (the far corners) are
    /// compressed; the adjacent ones keep recursing.
    fn build_strong_offdiag(&mut self, a: &Mat, r0: usize, c0: usize, size: usize, rank: usize) {
        if size <= self.leaf_size {
            self.dense.push(DenseBlock {
                r0,
                c0,
                m: submat(a, r0, c0, size, size),
            });
            return;
        }
        let half = size / 2;
        for (dr, dc) in [(0, 0), (0, half), (half, 0), (half, half)] {
            let (rr, cc) = (r0 + dr, c0 + dc);
            // Separation in units of the child block size at this level.
            let sep = (rr as isize - cc as isize).unsigned_abs() / half;
            if sep >= 2 {
                self.compress(a, rr, cc, half, rank);
            } else {
                self.build_strong_offdiag(a, rr, cc, half, rank);
            }
        }
    }

    fn compress(&mut self, a: &Mat, r0: usize, c0: usize, size: usize, rank: usize) {
        let block = submat(a, r0, c0, size, size);
        let (u, v) = low_rank_approx(&block, rank);
        self.low_rank.push(LowRankBlock { r0, c0, u, v });
    }

    /// `y = H x` touching only the factored representation.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0f32; self.n];
        for d in &self.dense {
            let xs = &x[d.c0..d.c0 + d.m.cols];
            for i in 0..d.m.rows {
                y[d.r0 + i] += crate::tensor::dot(d.m.row(i), xs);
            }
        }
        for b in &self.low_rank {
            let xs = &x[b.c0..b.c0 + b.v.rows];
            // tmp = V^T xs  (k)
            let tmp = b.v.matvec_t(xs);
            // y += U tmp
            for i in 0..b.u.rows {
                y[b.r0 + i] += crate::tensor::dot(b.u.row(i), &tmp);
            }
        }
        y
    }

    /// Reconstruct the dense matrix (tests / small n only).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.n, self.n);
        for d in &self.dense {
            for i in 0..d.m.rows {
                for j in 0..d.m.cols {
                    *out.at_mut(d.r0 + i, d.c0 + j) += d.m.at(i, j);
                }
            }
        }
        for b in &self.low_rank {
            let prod = b.u.matmul_nt(&b.v);
            for i in 0..prod.rows {
                for j in 0..prod.cols {
                    *out.at_mut(b.r0 + i, b.c0 + j) += prod.at(i, j);
                }
            }
        }
        out
    }

    /// Storage cost in floats — `O(k n log n)` for weak admissibility.
    pub fn storage_floats(&self) -> usize {
        let lr: usize = self
            .low_rank
            .iter()
            .map(|b| b.u.rows * b.u.cols + b.v.rows * b.v.cols)
            .sum();
        let de: usize = self.dense.iter().map(|d| d.m.rows * d.m.cols).sum();
        lr + de
    }

    /// Multiply-add count of one matvec (the App. B.4 cost comparison).
    pub fn matvec_flops(&self) -> usize {
        let lr: usize = self
            .low_rank
            .iter()
            .map(|b| b.u.rows * b.u.cols + b.v.rows * b.v.cols)
            .sum();
        let de: usize = self.dense.iter().map(|d| d.m.rows * d.m.cols).sum();
        lr + de
    }
}

fn submat(a: &Mat, r0: usize, c0: usize, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |i, j| a.at(r0 + i, c0 + j))
}

/// Rank-`k` approximation `B ≈ U V^T` via orthogonal iteration on `B B^T`.
/// Exact when `rank(B) <= k` (the case for our structured masks).
pub fn low_rank_approx(b: &Mat, k: usize) -> (Mat, Mat) {
    let k = k.min(b.rows).min(b.cols);
    // Initialize U with deterministic pseudo-random values.
    let mut rng = crate::util::Rng::new(0x10D1);
    let mut u = Mat::randn(b.rows, k, 1.0, &mut rng);
    for _ in 0..12 {
        // v = B^T u ; orthonormalize; u = B v ; orthonormalize
        let v = b.matmul_tn(&u); // wait: need B^T @ U -> (cols,k)
        let v = gram_schmidt(&v);
        u = b.matmul(&v);
        u = gram_schmidt(&u);
    }
    // V^T = U^T B  =>  V = B^T U
    let v = b.matmul_tn(&u);
    (u, v)
}

/// Column-wise modified Gram–Schmidt with rank-deficiency handling: a
/// column whose residual norm collapses relative to its original norm is
/// numerical noise (the input had lower rank than requested) and is zeroed
/// rather than normalized — normalizing would amplify fp noise into a
/// spurious non-orthogonal direction. Each column is orthogonalized twice
/// ("twice is enough") for stability.
fn gram_schmidt(a: &Mat) -> Mat {
    let mut q = a.clone();
    let (n, k) = (q.rows, q.cols);
    for j in 0..k {
        let mut orig_norm = 0.0f32;
        for i in 0..n {
            orig_norm += q.at(i, j) * q.at(i, j);
        }
        let orig_norm = orig_norm.sqrt();
        for _pass in 0..2 {
            for p in 0..j {
                let mut dot = 0.0f32;
                for i in 0..n {
                    dot += q.at(i, j) * q.at(i, p);
                }
                for i in 0..n {
                    *q.at_mut(i, j) -= dot * q.at(i, p);
                }
            }
        }
        let mut norm = 0.0f32;
        for i in 0..n {
            norm += q.at(i, j) * q.at(i, j);
        }
        let norm = norm.sqrt();
        if norm > 1e-4 * orig_norm.max(1e-30) {
            for i in 0..n {
                *q.at_mut(i, j) /= norm;
            }
        } else {
            for i in 0..n {
                *q.at_mut(i, j) = 0.0;
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::assert_close;
    use crate::util::Rng;

    /// A rank-1-off-diagonal test matrix: M[i][j] = r_i * c_j (i != j
    /// blocks exactly rank 1), plus dense diagonal noise.
    fn structured_matrix(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let r: Vec<f32> = (0..n).map(|_| rng.range_f32(0.5, 1.5)).collect();
        let c: Vec<f32> = (0..n).map(|_| rng.range_f32(0.5, 1.5)).collect();
        Mat::from_fn(n, n, |i, j| {
            r[i] * c[j] + if i == j { 1.0 } else { 0.0 }
        })
    }

    #[test]
    fn weak_hodlr_reconstructs_rank1_structure() {
        let a = structured_matrix(32, 1);
        let h = Hodlr::from_dense(&a, 4, 2, Admissibility::Weak);
        assert_close(&h.to_dense(), &a, 1e-3, 1e-3);
    }

    #[test]
    fn strong_hodlr_reconstructs_too() {
        let a = structured_matrix(32, 2);
        let h = Hodlr::from_dense(&a, 4, 2, Admissibility::Strong);
        assert_close(&h.to_dense(), &a, 1e-3, 1e-3);
    }

    #[test]
    fn matvec_matches_dense() {
        let a = structured_matrix(64, 3);
        let h = Hodlr::from_dense(&a, 8, 2, Admissibility::Weak);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..64).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let y_fast = h.matvec(&x);
        let y_dense = a.matvec(&x);
        for i in 0..64 {
            assert!((y_fast[i] - y_dense[i]).abs() < 1e-2, "i={i}: {} vs {}", y_fast[i], y_dense[i]);
        }
    }

    #[test]
    fn weak_storage_is_subquadratic() {
        let a = structured_matrix(256, 5);
        let h = Hodlr::from_dense(&a, 8, 2, Admissibility::Weak);
        // O(k n log n) with k=2: generously < n^2 / 4 at n=256
        assert!(h.storage_floats() < 256 * 256 / 4, "storage={}", h.storage_floats());
    }

    #[test]
    fn strong_costs_more_than_weak_but_constant_factor() {
        // The App. B.4 observation: strong admissibility is a constant
        // factor more expensive (paper saw ~4x in their Triton kernel).
        let a = structured_matrix(256, 6);
        let hw = Hodlr::from_dense(&a, 8, 2, Admissibility::Weak);
        let hs = Hodlr::from_dense(&a, 8, 2, Admissibility::Strong);
        let (fw, fs) = (hw.matvec_flops(), hs.matvec_flops());
        assert!(fs > fw, "strong {fs} should cost more than weak {fw}");
        assert!(fs < 8 * fw, "should stay a constant factor ({fs} vs {fw})");
    }

    #[test]
    fn low_rank_approx_exact_for_low_rank_input() {
        let mut rng = Rng::new(7);
        let u = Mat::randn(16, 2, 1.0, &mut rng);
        let v = Mat::randn(12, 2, 1.0, &mut rng);
        let b = u.matmul_nt(&v);
        let (uu, vv) = low_rank_approx(&b, 2);
        assert_close(&uu.matmul_nt(&vv), &b, 1e-3, 1e-3);
    }
}
