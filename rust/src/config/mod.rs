//! Run configuration: JSON file + CLI overrides (`--key value` wins over
//! file values, file wins over defaults). serde is unavailable offline, so
//! this rides on `util::json` + `util::cli`.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::util::cli::Args;
use crate::util::json::Json;

/// Configuration shared by the experiment commands.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// artifact set name prefix, e.g. "tiny" or "lm"
    pub config: String,
    /// model variant, e.g. "loglinear_mamba2"
    pub variant: String,
    pub artifacts: PathBuf,
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub seed: u64,
    pub eval_batches: usize,
    pub out: Option<PathBuf>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            config: "tiny".into(),
            variant: "loglinear_mamba2".into(),
            artifacts: crate::runtime::artifacts_dir(),
            steps: 200,
            lr: 3e-3,
            warmup: 20,
            seed: 0,
            eval_batches: 8,
            out: None,
        }
    }
}

impl RunConfig {
    /// artifact set name, e.g. "tiny_loglinear_mamba2"
    pub fn model_name(&self) -> String {
        format!("{}_{}", self.config, self.variant)
    }

    /// Layer: defaults <- JSON file (`--config-file`) <- CLI options.
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(path) = args.get("config-file") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config file {path}"))?;
            let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
            cfg.apply_json(&j);
        }
        cfg.config = args.str_or("config", &cfg.config);
        cfg.variant = args.str_or("variant", &cfg.variant);
        if let Some(a) = args.get("artifacts") {
            cfg.artifacts = PathBuf::from(a);
        }
        cfg.steps = args.usize_or("steps", cfg.steps);
        cfg.lr = args.f64_or("lr", cfg.lr);
        cfg.warmup = args.usize_or("warmup", cfg.warmup);
        cfg.seed = args.u64_or("seed", cfg.seed);
        cfg.eval_batches = args.usize_or("eval-batches", cfg.eval_batches);
        cfg.out = args.get("out").map(PathBuf::from);
        Ok(cfg)
    }

    fn apply_json(&mut self, j: &Json) {
        if let Some(v) = j.get("config").and_then(|v| v.as_str()) {
            self.config = v.to_string();
        }
        if let Some(v) = j.get("variant").and_then(|v| v.as_str()) {
            self.variant = v.to_string();
        }
        if let Some(v) = j.get("artifacts").and_then(|v| v.as_str()) {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = j.get("steps").and_then(|v| v.as_usize()) {
            self.steps = v;
        }
        if let Some(v) = j.get("lr").and_then(|v| v.as_f64()) {
            self.lr = v;
        }
        if let Some(v) = j.get("warmup").and_then(|v| v.as_usize()) {
            self.warmup = v;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_usize()) {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("eval_batches").and_then(|v| v.as_usize()) {
            self.eval_batches = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cli_overrides_defaults() {
        let args = Args::parse("train --variant gdn --steps 42 --lr 1e-4".split_whitespace());
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.variant, "gdn");
        assert_eq!(cfg.steps, 42);
        assert!((cfg.lr - 1e-4).abs() < 1e-12);
        assert_eq!(cfg.model_name(), "tiny_gdn");
    }

    #[test]
    fn file_then_cli_priority() {
        let dir = std::env::temp_dir().join("loglinear_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"variant": "mamba2", "steps": 7, "lr": 0.5}"#).unwrap();
        let argline = format!("train --config-file {} --steps 99", path.display());
        let args = Args::parse(argline.split_whitespace());
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.variant, "mamba2"); // from file
        assert_eq!(cfg.steps, 99); // CLI wins
        assert!((cfg.lr - 0.5).abs() < 1e-12); // from file
    }
}
