//! # loglinear — Log-Linear Attention, reproduced as a three-layer system
//!
//! This crate is the Layer-3 (Rust) portion of a Rust + JAX + Pallas
//! reproduction of *"Log-Linear Attention"* (Guo, Yang, Goel, Xing, Dao,
//! Kim; 2025). It contains:
//!
//! - [`fenwick`] — the Fenwick-tree prefix partitioning of §3.1,
//! - [`hmatrix`] — semiseparable / HODLR / quasi-hierarchical masks (§2, App. B),
//! - [`attention`] — a pure-Rust attention zoo (softmax, linear, Mamba-2,
//!   DeltaNet, Gated DeltaNet and their log-linear lifts) in recurrent,
//!   parallel, and chunkwise forms — the correctness oracles and the CPU
//!   performance substrate for the paper's benchmarks,
//! - [`state`] — the `O(log T)` Fenwick state manager used at decode time,
//! - [`prefill`] — the chunkwise prompt-ingestion subsystem: head-batched
//!   `O(T log T)` prefill engines with per-token chunk outputs, the
//!   sequential L-layer stack (`prefill::stack`), the shared scratch
//!   workspace, and the state-export bridge into the pooled decode path,
//! - [`runtime`] — the PJRT bridge that loads AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust,
//! - [`coordinator`] — the serving coordinator (router, dynamic batcher,
//!   decode scheduler) and training orchestrator,
//! - [`train`], [`eval`], [`data`] — training driver, evaluation harness,
//!   and synthetic workload generators for every table/figure in the paper,
//! - [`obs`] — serving-stack observability: the zero-alloc span recorder,
//!   kernel flop accounting, the metrics registry (log-bucketed latency
//!   histograms), and Chrome-trace / timeline / text exporters
//!   (docs/OBSERVABILITY.md),
//! - [`tensor`], [`util`], [`bench`] — from-scratch substrates (tensor math,
//!   RNG, JSON, CLI, stats, thread pool, property testing, bench harness);
//!   the build is fully offline so no external crates beyond `xla` are used.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod util;
pub mod obs;
pub mod tensor;
pub mod fenwick;
pub mod hmatrix;
pub mod attention;
pub mod state;
pub mod prefill;
pub mod runtime;
pub mod coordinator;
pub mod data;
pub mod config;
pub mod train;
pub mod eval;
pub mod bench;
