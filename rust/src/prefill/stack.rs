//! Sequential L-layer chunkwise prefill: the paper's evaluated models
//! (log-linear Mamba-2 / Gated DeltaNet LMs) are *stacks* — each layer's
//! per-token outputs are the next layer's inputs — and this module is
//! that stack for the serving prefill path.
//!
//! [`LayerStack`] threads one prompt chunk through `L`
//! [`PrefillEngine`]s **layer by layer**: layer 0 ingests the chunk's
//! token embeddings (q/k/v gathered by the caller), producing its
//! per-token chunk output `O_c^{(0)}: (C, H·d_v)` via the engine's
//! [`ChunkOutput`] mode (intra-chunk masked attention + inter-chunk level
//! read — the full chunkwise form); layer `ℓ+1`'s q/k/v are then
//! *projections* of `O_c^{(ℓ)}` ([`LayerProjection`]: one
//! `(H·d, H·d_v)` matrix per input stream, applied as a single GEMM over
//! the chunk, keys L2-normalized per (token, head) exactly like the
//! decode path's [`normalize_keys`]), and layer `ℓ+1` ingests the same
//! chunk positions. The last layer's `O_c` is the stack's hidden output —
//! the logits operand for prompt scoring
//! (`coordinator::backend::PooledBackend::score_chunk`).
//!
//! Both serving consumers and the differential oracle drive this *same*
//! code with the *same* gathered inputs, so a chunkwise-prefilled
//! sequence's decode trajectory is bit-identical between the pooled
//! serving path and the per-sequence replay — the contract
//! `coordinator::trace` enforces. Equivalence to a naive per-token,
//! per-layer recurrent reference (each layer an independent
//! `loglinear_{mamba2,gdn}::recurrent` sweep over the previous layer's
//! outputs) holds within the usual chunkwise tolerance and is asserted
//! below for L = 2, 3 and both transition families.
//!
//! Gate schedules come from one [`GateTable`] per layer — the same
//! tables the decode step reads — and all scratch lives in the shared
//! [`Workspace`] (one per server, not per sequence).

use crate::state::{level_weight, GateTable, TransitionKind};
use crate::tensor::{self, Mat};
use crate::util::Rng;

use super::engine::{ChunkOutput, PrefillEngine, Workspace};

/// Input projections for one sequential layer `ℓ ≥ 1`: the previous
/// layer's per-token output `o ∈ R^{H·d_v}` maps to this layer's stacked
/// per-head queries/keys/values as `q = W_q o`, `k = W_k o` (then
/// per-head L2 normalization), `v = W_v o`. Row block `h·d..(h+1)·d` of
/// each matrix is head `h`'s projection, so one `(C, H·d_v) @ W^T` GEMM
/// produces every head's inputs for a whole chunk (and one
/// `(n, H·d_v) @ W^T` GEMM does the same for a decode batch).
///
/// In the sharded serving path this boundary doubles as the **pipeline
/// register**: the pipelined decode step carries each shard's per-row
/// output `o` across layers in a shard-local buffer and applies these
/// projections per shard, so the only data crossing a layer boundary is
/// exactly what crosses it in the layer-wise path — which is why
/// pipelining cannot change a sequence's bits (see docs/SHARDING.md).
#[derive(Debug, Clone)]
pub struct LayerProjection {
    /// query projection, `(H·d_k, H·d_v)`
    pub wq: Mat,
    /// key projection, `(H·d_k, H·d_v)` (outputs are L2-normalized per
    /// head before use — [`normalize_keys`])
    pub wk: Mat,
    /// value projection, `(H·d_v, H·d_v)`
    pub wv: Mat,
}

impl LayerProjection {
    /// Random projection with `1/sqrt(H·d_v)`-scaled entries (the same
    /// convention as the backend's embedding draws).
    pub fn random(heads: usize, dk: usize, dv: usize, rng: &mut Rng) -> LayerProjection {
        let fan_in = heads * dv;
        let s = 1.0 / (fan_in as f32).sqrt();
        LayerProjection {
            wq: Mat::randn(heads * dk, fan_in, s, rng),
            wk: Mat::randn(heads * dk, fan_in, s, rng),
            wv: Mat::randn(heads * dv, fan_in, s, rng),
        }
    }
}

/// L2-normalize every contiguous `d_k`-slice of a packed key buffer
/// (`(rows, H·d_k)` token-major or `(H, C, d_k)` head-major — both are a
/// sequence of per-(token, head) key vectors). THE one key-normalization
/// op for sequential layers: prefill (chunk projections) and decode
/// (batch projections) call it on the same per-key slices, so the two
/// paths produce bit-identical keys.
pub fn normalize_keys(buf: &mut [f32], dk: usize) {
    debug_assert_eq!(buf.len() % dk, 0);
    for k in buf.chunks_mut(dk) {
        let n = crate::tensor::ops::l2_norm(k).max(1e-6);
        for x in k.iter_mut() {
            *x /= n;
        }
    }
}

/// Restack a token-major `(C, H·d)` projection output into the engine's
/// head-major `(H, C, d)` layout.
fn restack_head_major(src: &[f32], heads: usize, c: usize, d: usize, dst: &mut Vec<f32>) {
    debug_assert_eq!(src.len(), c * heads * d);
    dst.clear();
    dst.resize(heads * c * d, 0.0);
    for head in 0..heads {
        for i in 0..c {
            dst[(head * c + i) * d..(head * c + i + 1) * d]
                .copy_from_slice(&src[(i * heads + head) * d..(i * heads + head + 1) * d]);
        }
    }
}

/// Sequential stack of per-layer chunkwise prefill engines (see module
/// docs). Holds only level states and the last chunk's final-layer
/// output; all scratch is the caller's shared [`Workspace`].
#[derive(Debug)]
pub struct LayerStack {
    heads: usize,
    dk: usize,
    dv: usize,
    chunk: usize,
    engines: Vec<PrefillEngine>,
    /// the last ingested chunk's final-layer outputs, `(C, H·d_v)`
    o_last: Vec<f32>,
}

impl LayerStack {
    pub fn new(layers: usize, heads: usize, dk: usize, dv: usize, chunk: usize) -> LayerStack {
        assert!(layers >= 1, "at least one layer");
        LayerStack {
            heads,
            dk,
            dv,
            chunk,
            engines: (0..layers).map(|_| PrefillEngine::new(heads, dk, dv, chunk)).collect(),
            o_last: Vec::new(),
        }
    }

    /// Seed a stack at the post-merge boundary of `z` already-ingested
    /// chunks from cached per-(layer, head) exports:
    /// `states[l·heads + h]` is the `(token_level, state)` list
    /// [`LayerStack::export_head`]`(l, h)` produced — the prefix cache's
    /// entry layout. Chunkwise ingestion resumes at chunk `z` **bit-
    /// exactly** (see [`PrefillEngine::from_boundary`]): a cache hit's
    /// continuation is indistinguishable from a cold prefill of the whole
    /// prompt.
    pub fn from_boundary(
        layers: usize,
        heads: usize,
        dk: usize,
        dv: usize,
        chunk: usize,
        z: usize,
        states: &[Vec<(usize, &[f32])>],
    ) -> LayerStack {
        assert!(layers >= 1, "at least one layer");
        assert_eq!(states.len(), layers * heads, "one level list per (layer, head)");
        LayerStack {
            heads,
            dk,
            dv,
            chunk,
            engines: (0..layers)
                .map(|l| {
                    PrefillEngine::from_boundary(
                        heads,
                        dk,
                        dv,
                        chunk,
                        z,
                        &states[l * heads..(l + 1) * heads],
                    )
                })
                .collect(),
            o_last: Vec::new(),
        }
    }

    pub fn layers(&self) -> usize {
        self.engines.len()
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk
    }

    /// Tokens ingested so far (every layer is at the same position).
    pub fn tokens(&self) -> usize {
        self.engines[0].tokens()
    }

    /// Chunks ingested so far.
    pub fn chunks(&self) -> usize {
        self.engines[0].chunks()
    }

    pub fn is_finished(&self) -> bool {
        self.engines[0].is_finished()
    }

    /// One layer's engine (export plumbing:
    /// [`crate::prefill::bridge::export_prefill_head`]).
    pub fn engine(&self, layer: usize) -> &PrefillEngine {
        &self.engines[layer]
    }

    /// The last ingested chunk's final-layer per-token outputs,
    /// `(C, H·d_v)` row-major — empty before the first chunk, and empty
    /// after a state-only ingest (`want_output = false`).
    pub fn last_output(&self) -> &[f32] {
        &self.o_last
    }

    /// Resident state bytes across all layers (scratch excluded — it
    /// lives in the shared workspace).
    pub fn state_bytes(&self) -> usize {
        self.engines.iter().map(|e| e.state_bytes()).sum::<usize>() + self.o_last.len() * 4
    }

    /// Ingest one chunk through every layer sequentially. `qs0/ks0/vs0`
    /// are layer 0's stacked `(H, C, d)` head-major inputs (token
    /// embeddings; keys already normalized), `pos` the chunk's first
    /// absolute position (must equal [`LayerStack::tokens`]), `projs` the
    /// `L−1` inter-layer projections, `gates` one α/β/λ table per layer.
    ///
    /// Intermediate layers always compute per-token outputs (the next
    /// layer's inputs). `want_output` controls the **last** layer:
    /// scoring needs its `(C, H·d_v)` per-token outputs (returned), a
    /// generation prompt does not — pass `false` and the last layer runs
    /// state-only (for L = 1 that is exactly the cheap state-only ingest
    /// of the pre-stack engine), returning an empty slice.
    #[allow(clippy::too_many_arguments)]
    pub fn ingest_chunk(
        &mut self,
        ws: &mut Workspace,
        kind: TransitionKind,
        projs: &[LayerProjection],
        gates: &[GateTable],
        pos: usize,
        qs0: &[f32],
        ks0: &[f32],
        vs0: &[f32],
        want_output: bool,
    ) -> &[f32] {
        let (h, c, dk, dv) = (self.heads, self.chunk, self.dk, self.dv);
        let layers = self.engines.len();
        assert_eq!(projs.len(), layers - 1, "one projection per layer transition");
        assert_eq!(gates.len(), layers, "one gate table per layer");
        assert_eq!(qs0.len(), h * c * dk, "qs0 shape");
        assert_eq!(ks0.len(), h * c * dk, "ks0 shape");
        assert_eq!(vs0.len(), h * c * dv, "vs0 shape");
        assert_eq!(pos, self.tokens(), "chunk position desync");

        // loaner buffers from the shared workspace (taken out so the
        // engine can borrow the workspace mutably during ingest)
        let mut q_in = std::mem::take(&mut ws.stack_q);
        let mut k_in = std::mem::take(&mut ws.stack_k);
        let mut v_in = std::mem::take(&mut ws.stack_v);
        let mut proj = std::mem::take(&mut ws.stack_proj);
        let mut alpha = std::mem::take(&mut ws.stack_alpha);
        let mut beta = std::mem::take(&mut ws.stack_beta);
        let mut o_prev = std::mem::take(&mut ws.stack_o_a);
        let mut o_cur = std::mem::take(&mut ws.stack_o_b);

        for l in 0..layers {
            if l == 0 {
                q_in.clear();
                q_in.extend_from_slice(qs0);
                k_in.clear();
                k_in.extend_from_slice(ks0);
                v_in.clear();
                v_in.extend_from_slice(vs0);
            } else {
                let p = &projs[l - 1];
                // q = O_prev W_q^T, one GEMM for the whole chunk
                proj.clear();
                proj.resize(c * h * dk, 0.0);
                tensor::gemm_nt_into(c, h * dv, h * dk, &o_prev, &p.wq.data, &mut proj, false);
                restack_head_major(&proj, h, c, dk, &mut q_in);
                // k = normalize(O_prev W_k^T) — normalized token-major,
                // the same per-key slices the decode path normalizes
                proj.clear();
                proj.resize(c * h * dk, 0.0);
                tensor::gemm_nt_into(c, h * dv, h * dk, &o_prev, &p.wk.data, &mut proj, false);
                normalize_keys(&mut proj, dk);
                restack_head_major(&proj, h, c, dk, &mut k_in);
                // v = O_prev W_v^T
                proj.clear();
                proj.resize(c * h * dv, 0.0);
                tensor::gemm_nt_into(c, h * dv, h * dv, &o_prev, &p.wv.data, &mut proj, false);
                restack_head_major(&proj, h, c, dv, &mut v_in);
            }
            // per-(head, token) gates from this layer's table — the same
            // source the decode step reads
            alpha.clear();
            beta.clear();
            for head in 0..h {
                for j in 0..c {
                    alpha.push(gates[l].alpha_h(head, pos + j));
                    beta.push(gates[l].beta_h(head, pos + j));
                }
            }
            o_cur.clear();
            let gt = &gates[l];
            let lam =
                move |head: usize, i: usize, lvl: usize| level_weight(gt.lambda_h(head, pos + i), lvl);
            // the last layer's outputs are only needed for scoring;
            // state-only ingest otherwise (no intra-chunk attention, no
            // level read — the cheap generation-prefill path)
            let co = if l + 1 < layers || want_output {
                o_cur.resize(c * h * dv, 0.0);
                Some(ChunkOutput { qs: &q_in, lambda: &lam, out: &mut o_cur })
            } else {
                None
            };
            match kind {
                TransitionKind::Mamba2 => {
                    self.engines[l].ingest_chunk_mamba2(ws, &k_in, &v_in, &alpha, co)
                }
                TransitionKind::Gdn => {
                    self.engines[l].ingest_chunk_gdn(ws, &k_in, &v_in, &alpha, &beta, co)
                }
            }
            std::mem::swap(&mut o_prev, &mut o_cur);
        }
        self.o_last.clear();
        self.o_last.extend_from_slice(&o_prev);

        ws.stack_q = q_in;
        ws.stack_k = k_in;
        ws.stack_v = v_in;
        ws.stack_proj = proj;
        ws.stack_alpha = alpha;
        ws.stack_beta = beta;
        ws.stack_o_a = o_prev;
        ws.stack_o_b = o_cur;
        &self.o_last
    }

    /// Seal every layer at the chunk boundary (the export precondition).
    pub fn finish(&mut self) {
        for eng in self.engines.iter_mut() {
            eng.finish();
        }
    }

    /// One (layer, head)'s live levels, ready for
    /// `{Pooled,}FenwickState::import_levels`. Requires
    /// [`LayerStack::finish`].
    pub fn export_head(&self, layer: usize, head: usize) -> Vec<(usize, &[f32])> {
        self.engines[layer].export_head(head)
    }
}

/// Test-only support shared across the crate's test suites (the stack
/// tests here and `coordinator::backend`'s): ONE naive per-token,
/// per-layer recurrent reference implementation, so the reference the
/// sequential stack is validated against cannot fork between modules.
#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::attention::{loglinear_gdn, loglinear_mamba2};

    /// Naive sequential-stack reference over explicit per-head layer-0
    /// inputs: each layer is an independent
    /// `loglinear_{mamba2,gdn}::recurrent` sweep per head over the
    /// previous layer's per-token outputs (projected + key-normalized
    /// exactly like the real stack), completely bypassing the chunkwise
    /// engines, the workspace, and the batched passes. Returns the final
    /// layer's `(T, H·d_v)` outputs; `gates.len()` is the layer count.
    pub(crate) fn naive_sequential_outputs(
        kind: TransitionKind,
        qs0: &[Mat],
        ks0: &[Mat],
        vs0: &[Mat],
        projs: &[LayerProjection],
        gates: &[GateTable],
    ) -> Mat {
        let heads = qs0.len();
        let t = qs0[0].rows;
        let (dk, dv) = (qs0[0].cols, vs0[0].cols);
        let layers = gates.len();
        assert_eq!(projs.len(), layers - 1, "one projection per layer transition");
        let nl = crate::fenwick::num_levels(t);
        let mut o_prev = Mat::zeros(t, heads * dv);
        for l in 0..layers {
            let (qs, ks, vs): (Vec<Mat>, Vec<Mat>, Vec<Mat>) = if l == 0 {
                (qs0.to_vec(), ks0.to_vec(), vs0.to_vec())
            } else {
                let p = &projs[l - 1];
                let qall = o_prev.matmul_nt(&p.wq); // (T, H·dk)
                let mut kall = o_prev.matmul_nt(&p.wk);
                normalize_keys(&mut kall.data, dk);
                let vall = o_prev.matmul_nt(&p.wv); // (T, H·dv)
                let slice = |m: &Mat, d: usize, head: usize| {
                    Mat::from_fn(t, d, |i, j| m.at(i, head * d + j))
                };
                (
                    (0..heads).map(|head| slice(&qall, dk, head)).collect(),
                    (0..heads).map(|head| slice(&kall, dk, head)).collect(),
                    (0..heads).map(|head| slice(&vall, dv, head)).collect(),
                )
            };
            let mut o_next = Mat::zeros(t, heads * dv);
            for head in 0..heads {
                let alpha: Vec<f32> = (0..t).map(|i| gates[l].alpha_h(head, i)).collect();
                let beta: Vec<f32> = (0..t).map(|i| gates[l].beta_h(head, i)).collect();
                let lam = Mat::from_fn(t, nl, |i, lvl| {
                    level_weight(gates[l].lambda_h(head, i), lvl)
                });
                let o_h = match kind {
                    TransitionKind::Mamba2 => {
                        loglinear_mamba2::recurrent(&qs[head], &ks[head], &vs[head], &alpha, &lam)
                    }
                    TransitionKind::Gdn => loglinear_gdn::recurrent(
                        &qs[head], &ks[head], &vs[head], &alpha, &beta, &lam,
                    ),
                };
                for i in 0..t {
                    o_next.row_mut(i)[head * dv..(head + 1) * dv].copy_from_slice(o_h.row(i));
                }
            }
            o_prev = o_next;
        }
        o_prev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build layer-0 per-head inputs (keys normalized) and random
    /// per-layer gate tables / projections.
    struct Fixture {
        heads: usize,
        dk: usize,
        dv: usize,
        t_len: usize,
        qs: Vec<Mat>,
        ks: Vec<Mat>,
        vs: Vec<Mat>,
        gates: Vec<GateTable>,
        projs: Vec<LayerProjection>,
    }

    fn fixture(layers: usize, heads: usize, dk: usize, dv: usize, t_len: usize, seed: u64) -> Fixture {
        let mut rng = Rng::new(seed);
        let mut ks = Vec::new();
        let mut qs = Vec::new();
        let mut vs = Vec::new();
        for _ in 0..heads {
            qs.push(Mat::randn(t_len, dk, 1.0 / (dk as f32).sqrt(), &mut rng));
            let mut k = Mat::randn(t_len, dk, 1.0, &mut rng);
            for i in 0..t_len {
                normalize_keys(k.row_mut(i), dk);
            }
            ks.push(k);
            vs.push(Mat::randn(t_len, dv, 1.0, &mut rng));
        }
        let gates = (0..layers)
            .map(|_| {
                let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.85, 1.0)).collect();
                let beta: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.1, 0.9)).collect();
                let lambda = Mat::rand_uniform(t_len, 6, 0.05, 1.0, &mut rng);
                GateTable::per_token(alpha, lambda).with_beta(beta)
            })
            .collect();
        let projs =
            (1..layers).map(|_| LayerProjection::random(heads, dk, dv, &mut rng)).collect();
        Fixture { heads, dk, dv, t_len, qs, ks, vs, gates, projs }
    }

    /// Naive per-token, per-layer recurrent reference over the fixture's
    /// layer-0 inputs (the ONE shared implementation in
    /// [`test_support::naive_sequential_outputs`]).
    fn naive_stack_reference(fx: &Fixture, kind: TransitionKind, layers: usize) -> Mat {
        test_support::naive_sequential_outputs(
            kind,
            &fx.qs,
            &fx.ks,
            &fx.vs,
            &fx.projs,
            &fx.gates[..layers],
        )
    }

    /// Run the chunkwise stack over every full chunk, returning the
    /// concatenated `(T, H·d_v)` outputs.
    fn run_stack(fx: &Fixture, kind: TransitionKind, layers: usize, c: usize) -> Mat {
        let (h, dk, dv, t) = (fx.heads, fx.dk, fx.dv, fx.t_len);
        assert_eq!(t % c, 0);
        let mut ws = Workspace::new();
        let mut stack = LayerStack::new(layers, h, dk, dv, c);
        let mut out = Mat::zeros(t, h * dv);
        for z in 0..t / c {
            let (s, e) = (z * c, (z + 1) * c);
            let mut q0 = Vec::new();
            let mut k0 = Vec::new();
            let mut v0 = Vec::new();
            for head in 0..h {
                q0.extend_from_slice(fx.qs[head].rows_data(s, e));
                k0.extend_from_slice(fx.ks[head].rows_data(s, e));
                v0.extend_from_slice(fx.vs[head].rows_data(s, e));
            }
            let o = stack.ingest_chunk(&mut ws, kind, &fx.projs, &fx.gates, s, &q0, &k0, &v0, true);
            out.rows_data_mut(s, e).copy_from_slice(o);
        }
        out
    }

    /// THE sequential-stack equivalence: L = 2, 3 chunkwise stacks match
    /// the naive per-token per-layer recurrent reference within chunkwise
    /// tolerance, for both transition families.
    #[test]
    fn sequential_stack_matches_naive_per_layer_recurrent_reference() {
        for &(layers, c, seed) in &[(2usize, 4usize, 0x57Au64), (3, 8, 0x57B)] {
            let fx = fixture(layers, 2, 6, 5, 24.max(c * 3), seed);
            for kind in [TransitionKind::Mamba2, TransitionKind::Gdn] {
                let want = naive_stack_reference(&fx, kind, layers);
                let got = run_stack(&fx, kind, layers, c);
                for i in 0..fx.t_len {
                    for j in 0..fx.heads * fx.dv {
                        let (g, w) = (got.at(i, j), want.at(i, j));
                        assert!(
                            (g - w).abs() < 5e-3 + 1e-2 * w.abs(),
                            "L={layers} {kind:?} t={i} j={j}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    /// A 1-layer stack is exactly the bare engine's ChunkOutput mode
    /// (bit-exact), and sharing one workspace across two stacks changes
    /// nothing — the serving pattern (many sequences, one scratch pool).
    #[test]
    fn one_layer_stack_equals_bare_engine_and_workspace_sharing_is_inert() {
        let fx = fixture(1, 2, 6, 5, 16, 0x57C);
        let (h, dk, dv, c, t) = (fx.heads, fx.dk, fx.dv, 4usize, fx.t_len);
        for kind in [TransitionKind::Mamba2, TransitionKind::Gdn] {
            // two stacks interleaved over one shared workspace
            let mut ws = Workspace::new();
            let mut a = LayerStack::new(1, h, dk, dv, c);
            let mut b = LayerStack::new(1, h, dk, dv, c);
            let mut out_a = Mat::zeros(t, h * dv);
            let mut eng = PrefillEngine::new(h, dk, dv, c);
            let mut eng_ws = Workspace::new();
            for z in 0..t / c {
                let (s, e) = (z * c, (z + 1) * c);
                let mut q0 = Vec::new();
                let mut k0 = Vec::new();
                let mut v0 = Vec::new();
                for head in 0..h {
                    q0.extend_from_slice(fx.qs[head].rows_data(s, e));
                    k0.extend_from_slice(fx.ks[head].rows_data(s, e));
                    v0.extend_from_slice(fx.vs[head].rows_data(s, e));
                }
                let o = a.ingest_chunk(&mut ws, kind, &[], &fx.gates, s, &q0, &k0, &v0, true);
                out_a.rows_data_mut(s, e).copy_from_slice(o);
                // the second stack sees the dirtied workspace
                let _ = b.ingest_chunk(&mut ws, kind, &[], &fx.gates, s, &q0, &k0, &v0, true);

                // bare engine with the same ChunkOutput request
                let gt = &fx.gates[0];
                let mut alpha = Vec::new();
                let mut beta = Vec::new();
                for head in 0..h {
                    for j in 0..c {
                        alpha.push(gt.alpha_h(head, s + j));
                        beta.push(gt.beta_h(head, s + j));
                    }
                }
                let lam = |head: usize, i: usize, lvl: usize| {
                    level_weight(gt.lambda_h(head, s + i), lvl)
                };
                let mut out = vec![0.0f32; c * h * dv];
                let co = ChunkOutput { qs: &q0, lambda: &lam, out: &mut out };
                match kind {
                    TransitionKind::Mamba2 => {
                        eng.ingest_chunk_mamba2(&mut eng_ws, &k0, &v0, &alpha, Some(co))
                    }
                    TransitionKind::Gdn => {
                        eng.ingest_chunk_gdn(&mut eng_ws, &k0, &v0, &alpha, &beta, Some(co))
                    }
                }
                assert_eq!(out_a.rows_data(s, e), &out[..], "{kind:?} chunk {z}: stack != engine");
            }
            // interleaving over one workspace left both stacks identical
            a.finish();
            b.finish();
            for head in 0..h {
                assert_eq!(
                    a.export_head(0, head),
                    b.export_head(0, head),
                    "{kind:?} head {head}: workspace sharing changed states"
                );
            }
        }
    }
}
