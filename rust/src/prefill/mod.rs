//! Chunkwise prompt prefill (paper Alg. 1, turned into a serving
//! subsystem).
//!
//! Until this module existed the serving engine ingested prompts the slow
//! way: one token at a time through the recurrent decode step, O(T)
//! scalar state updates per sequence, even though the chunkwise engines
//! of [`crate::attention::loglinear_mamba2`] and
//! [`crate::attention::loglinear_gdn`] already implement the O(T log T)
//! matmul-rich form. The pieces here close that gap:
//!
//! - [`engine::PrefillEngine`] — a **head-batched** chunkwise ingester:
//!   H heads' chunk-granularity Fenwick level states are stored stacked,
//!   so every per-chunk product (`K_c^T diag(w) V_c` state writes,
//!   `Φ_chunk S` carried-state transitions, the `Q_c S_cat` level read)
//!   runs as **one batched GEMM dispatch over all heads**
//!   ([`crate::tensor::batch`]). Two modes: *state-only* (a generation
//!   prompt needs no logits until its final token — a chunk costs one
//!   state write + one transition pass) and *per-token output*
//!   ([`engine::ChunkOutput`]): the full chunkwise form — intra-chunk
//!   masked attention **plus** the inter-chunk level read — emitting a
//!   `(C, H·d_v)` output block per chunk. Per-chunk scratch lives in one
//!   [`engine::Workspace`] **shared across all sequences** (ROADMAP
//!   item) instead of per-engine buffers.
//! - [`stack::LayerStack`] — the **sequential L-layer stack**: layer ℓ's
//!   per-token chunk outputs are projected
//!   ([`stack::LayerProjection`]) into layer ℓ+1's q/k/v (keys
//!   re-normalized per token) before ℓ+1 ingests the same chunk — the
//!   paper's actual model shape, and the producer of the last-layer
//!   hidden outputs that prompt scoring turns into per-token log-probs.
//! - [`bridge`] — the **state-export bridge**: converts a chunk-granularity
//!   hierarchy ([`crate::attention::loglinear::ChunkFenwick`] or one
//!   [`engine::PrefillEngine`] head) at an arbitrary chunk-aligned
//!   position into [`crate::state::PooledFenwickState`] pool blocks. The
//!   alignment fact that makes this exact: after `z` chunks of size
//!   `C = 2^lc`, the token-granularity Fenwick machine at the *post-merge
//!   boundary* of step `t = z·C` holds exactly the levels
//!   `{lc + m : chunk-level m live}` — the same layout, one relabel.
//!
//! The serving integration lives in
//! [`crate::coordinator::backend::PooledBackend`] (one `LayerStack` per
//! prefilling sequence, lazy export on the first decode step, the
//! `score_*` prompt-scoring path) and the engine loop of
//! [`crate::coordinator::server::DecodeServer`] (prompts advance chunks
//! under a per-step flop budget, interleaved with running decode rows).
//! Gates come from the per-layer [`crate::state::GateTable`]s — shared or
//! per-head schedules — so prefill and decode read the same
//! position-dependent α/β/λ, and a chunkwise-prefilled sequence's decode
//! trajectory is bit-identical to the per-sequence oracle replay (the
//! serving-trace differential harness in `coordinator::trace` pins this).

pub mod bridge;
pub mod engine;
pub mod stack;

pub use bridge::{export_chunk_fenwick, export_prefill_head};
pub use engine::{ChunkOutput, PrefillEngine, Workspace};
pub use stack::{normalize_keys, LayerProjection, LayerStack};
