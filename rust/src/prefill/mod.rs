//! Chunkwise prompt prefill (paper Alg. 1, turned into a serving
//! subsystem).
//!
//! Until this module existed the serving engine ingested prompts the slow
//! way: one token at a time through the recurrent decode step, O(T)
//! scalar state updates per sequence, even though the chunkwise engines
//! of [`crate::attention::loglinear_mamba2`] and
//! [`crate::attention::loglinear_gdn`] already implement the O(T log T)
//! matmul-rich form. The pieces here close that gap:
//!
//! - [`engine::PrefillEngine`] — a **head-batched, state-only** chunkwise
//!   ingester: H heads' chunk-granularity Fenwick level states are stored
//!   stacked, so every per-chunk product (`K_c^T diag(w) V_c` state
//!   writes, `Φ_chunk S` carried-state transitions, the optional
//!   `Q_c S_cat` level read) runs as **one batched GEMM dispatch over all
//!   heads** ([`crate::tensor::batch`]) instead of H separate kernel
//!   launches — the multi-head widening the ROADMAP asked for, applied
//!   where chunks make the products wide. Serving prefill skips attention
//!   outputs entirely (only the final prompt token's logits matter, and
//!   the decode step produces those), so a chunk costs one state write +
//!   one transition pass instead of C recurrent steps.
//! - [`bridge`] — the **state-export bridge**: converts a chunk-granularity
//!   hierarchy ([`crate::attention::loglinear::ChunkFenwick`] or one
//!   [`engine::PrefillEngine`] head) at an arbitrary chunk-aligned
//!   position into [`crate::state::PooledFenwickState`] pool blocks. The
//!   alignment fact that makes this exact: after `z` chunks of size
//!   `C = 2^lc`, the token-granularity Fenwick machine at the *post-merge
//!   boundary* of step `t = z·C` holds exactly the levels
//!   `{lc + m : chunk-level m live}` — the same layout, one relabel.
//!
//! The serving integration lives in
//! [`crate::coordinator::backend::PooledBackend`] (per-sequence,
//! per-layer engines, lazy export on the first decode step) and the
//! engine loop of [`crate::coordinator::server::DecodeServer`] (prompts
//! advance one chunk per step, interleaved with running decode rows).
//! Gates come from the per-layer [`crate::state::GateTable`]s — `C`
//! shared or `H·C` head-major per-head schedules per chunk — so prefill
//! and decode read the same position- (and head-)dependent α/β/λ
//! schedules, and a chunkwise-prefilled sequence's decode trajectory is
//! bit-identical to a token-stepped one (the serving-trace differential
//! harness in `coordinator::trace` pins this).

pub mod bridge;
pub mod engine;

pub use bridge::{export_chunk_fenwick, export_prefill_head};
pub use engine::{LevelRead, PrefillEngine};
