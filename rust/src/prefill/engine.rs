//! Head-batched chunkwise prefill engine (paper Alg. 1, full form).
//!
//! [`PrefillEngine`] ingests a prompt one chunk at a time for **H heads
//! at once**. The level hierarchy itself *is* a
//! [`crate::attention::loglinear::ChunkFenwick`] — no mirrored merge
//! skeleton — holding **stacked** states: level `m` is one
//! `(H·d_k, d_v)` matrix whose rows `h·d_k..(h+1)·d_k` are head `h`'s
//! bucket state. Stacking is what lets every per-chunk product run
//! through the batched GEMM dispatch ([`crate::tensor::batch`]) as one
//! kernel launch covering all heads:
//!
//! - state write `S_new^h = K_c^{hT} diag(w) V_c^h` →
//!   [`crate::tensor::gemm_tn_diag_batch_acc`],
//! - GDN UT system `K_c^h K_c^{hT}` and the intra-chunk `Q_c^h K_c^{hT}`
//!   → [`crate::tensor::gemm_nt_batch_into`],
//! - GDN carried-state transition `Φ^h S^h` and the inter-chunk level
//!   read `Q_c^h S_cat^h` → [`crate::tensor::gemm_batch_into`].
//!
//! **Two ingestion modes.** State-only (pass `None` for the chunk
//! output): ingestion skips attention outputs entirely — one state write
//! + one transition pass per chunk — which is all a *generation* prompt
//! needs (the final prompt token's logits come from the decode step).
//! **Per-token output** (pass [`ChunkOutput`]): the engine additionally
//! computes the full chunk output
//! `O_c = (intra-chunk masked attention) + (inter-chunk level read)`,
//! i.e. both halves of the chunkwise algorithm — for Mamba-2 the masked
//! local `P = tril(Q_c K_c^T) ⊙ decay-ratio ⊙ Λ` plus the λ·decay-folded
//! `Q_c S_cat` read; for GDN the materialized local UT/Householder term
//! `P = (tril(Q_c K_c^T) ⊙ Gratio)(I + StrictTril(M))^{-1} diag(β) ⊙ Λ`
//! plus the effective-query read `Q̂_c S_cat` — written as a
//! **`(C, H·d_v)` row-major block** (token-major, heads concatenated per
//! row: the layout a sequential layer stack projects into the next
//! layer's q/k/v, see [`crate::prefill::stack`]). This is the intra-chunk
//! half the ROADMAP's prompt-scoring item called for.
//!
//! Per head and chunk, the op sequences mirror the single-head chunkwise
//! reference paths (`loglinear_mamba2::chunkwise` /
//! `loglinear_gdn::chunkwise`): the Mamba-2 path is **bit-exact** with
//! the per-head reference (states and outputs — asserted below), the GDN
//! path agrees within solver tolerance (the UT solves here are in-place
//! substitutions).
//!
//! **Shared workspace** (ROADMAP item): all per-chunk scratch — decay
//! tables, UT systems, concat/read buffers, the transition swap buffer —
//! lives in a [`Workspace`] passed into each ingest call instead of
//! per-engine fields, so a server holding hundreds of mid-prefill
//! sequences (L engines each) shares ONE scratch pool instead of
//! allocating `sequences · L` copies. Engines keep only their level
//! states. Results never depend on what a workspace previously held
//! (every buffer is cleared or fully overwritten before use;
//! regression-tested below by interleaving engines over one workspace).
//!
//! Gates (`α`, `β`) may be **shared or per-head**: ingest accepts either
//! `C` gates applied to every head or `H·C` head-major gates, matching
//! the pooled backend's per-head [`crate::state::GateTable`]. The shared
//! case is executed as the per-head case with the schedule replicated
//! bit-identically, so one code path serves both.

use crate::attention::deltanet::apply_householder_slice;
use crate::attention::loglinear::ChunkFenwick;
use crate::fenwick;
use crate::tensor;

/// Shared per-chunk scratch for any number of [`PrefillEngine`]s (and
/// [`crate::prefill::stack::LayerStack`]s): one instance per server (or
/// per thread), passed `&mut` into every ingest call. Holding it outside
/// the engine is what makes prefill memory scale with *live state*, not
/// with the number of concurrent prompts. Every buffer is cleared or
/// fully overwritten before each use, so results are independent of what
/// the workspace held before (asserted by tests).
#[derive(Debug, Default)]
pub struct Workspace {
    /// intra-chunk cumulative decays, head-major `(H, C)`
    g: Vec<f32>,
    /// per-token state-write weights, head-major `(H, C)`
    wscale: Vec<f32>,
    /// level-read concat `S_cat`, `(H·d_k, live·d_v)`
    cat: Vec<f32>,
    /// level-read GEMM output, `(H·C, live·d_v)`
    read_buf: Vec<f32>,
    /// live chunk levels at the last concat
    active_ids: Vec<usize>,
    /// GDN UT systems, `(H, C, C)`
    sys: Vec<f32>,
    /// GDN solved value rows `Ŵ`, `(H, C, d_v)`
    what: Vec<f32>,
    /// GDN materialized chunk transitions `Φ`, `(H, d_k, d_k)`
    phi: Vec<f32>,
    /// stacked transition swap buffer, `(H·d_k, d_v)`
    scratch: Vec<f32>,
    /// intra-chunk attention matrices `P`, `(H, C, C)`
    qk: Vec<f32>,
    /// GDN effective queries `Q̂`, `(H, C, d_k)`
    qe: Vec<f32>,
    /// GDN `−g`-scaled key rows for the UT effective-query GEMM, `(C, d_k)`
    kb: Vec<f32>,
    /// per-token outputs in stacked `(H, C, d_v)` form, pre-scatter
    o_stack: Vec<f32>,
    // ---- buffers loaned to LayerStack (layer-input restacking) ----
    pub(crate) stack_q: Vec<f32>,
    pub(crate) stack_k: Vec<f32>,
    pub(crate) stack_v: Vec<f32>,
    pub(crate) stack_proj: Vec<f32>,
    pub(crate) stack_alpha: Vec<f32>,
    pub(crate) stack_beta: Vec<f32>,
    pub(crate) stack_o_a: Vec<f32>,
    pub(crate) stack_o_b: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Resident scratch bytes (capacity, not length): what ONE shared
    /// workspace holds — and therefore what every additional concurrent
    /// prefill sequence now *doesn't* allocate. Reported by the prefill
    /// bench's shared-workspace section.
    pub fn bytes(&self) -> usize {
        4 * (self.g.capacity()
            + self.wscale.capacity()
            + self.cat.capacity()
            + self.read_buf.capacity()
            + self.sys.capacity()
            + self.what.capacity()
            + self.phi.capacity()
            + self.scratch.capacity()
            + self.qk.capacity()
            + self.qe.capacity()
            + self.kb.capacity()
            + self.o_stack.capacity()
            + self.stack_q.capacity()
            + self.stack_k.capacity()
            + self.stack_v.capacity()
            + self.stack_proj.capacity()
            + self.stack_alpha.capacity()
            + self.stack_beta.capacity()
            + self.stack_o_a.capacity()
            + self.stack_o_b.capacity())
            + std::mem::size_of::<usize>() * self.active_ids.capacity()
    }
}

/// Per-token chunk-output request riding along an ingest: the engine
/// computes `O_c = (intra-chunk masked attention) + (inter-chunk level
/// read over the pre-transition states)` for every head and writes it
/// token-major.
pub struct ChunkOutput<'a> {
    /// stacked queries `(H, C, d_k)`, head-major row-major
    pub qs: &'a [f32],
    /// λ lookup `(head, chunk-local row, token level) → weight`. Token
    /// levels: intra-chunk pairs use their local Fenwick level
    /// (`fenwick::level_of(i, j)`, which equals the absolute level for
    /// intra-chunk pairs), inter-chunk buckets use `log2(C) + m`. The
    /// engine folds all cumulative-decay factors itself; ignore the head
    /// argument for schedules shared across heads.
    pub lambda: &'a dyn Fn(usize, usize, usize) -> f32,
    /// chunk output `(C, H·d_v)` row-major — token-major, head outputs
    /// concatenated along each row. Overwritten.
    pub out: &'a mut [f32],
}

/// Multi-head chunk-granularity Fenwick state builder (see module docs).
#[derive(Debug)]
pub struct PrefillEngine {
    heads: usize,
    dk: usize,
    dv: usize,
    chunk: usize,
    /// chunks ingested so far
    z: usize,
    /// sealed by [`PrefillEngine::finish`]: level 0 merged, exportable
    finished: bool,
    /// the shared chunk-granularity hierarchy, holding stacked
    /// `(H·d_k, d_v)` states (head `h` = rows `h·d_k..(h+1)·d_k`)
    fen: ChunkFenwick,
}

impl PrefillEngine {
    pub fn new(heads: usize, dk: usize, dv: usize, chunk: usize) -> PrefillEngine {
        assert!(heads >= 1 && dk >= 1 && dv >= 1);
        assert!(chunk >= 1 && chunk.is_power_of_two(), "chunk size must be a power of two");
        PrefillEngine { heads, dk, dv, chunk, z: 0, finished: false, fen: ChunkFenwick::new() }
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    /// State shape per head.
    pub fn state_dims(&self) -> (usize, usize) {
        (self.dk, self.dv)
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk
    }

    /// Chunks ingested so far.
    pub fn chunks(&self) -> usize {
        self.z
    }

    /// Tokens ingested so far (`chunks · chunk_size`).
    pub fn tokens(&self) -> usize {
        self.z * self.chunk
    }

    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Live stacked level states (`popcount(z)` after finish).
    pub fn live_states(&self) -> usize {
        self.fen.live_states()
    }

    /// Resident bytes of live stacked states (scratch lives in the shared
    /// [`Workspace`], not here).
    pub fn state_bytes(&self) -> usize {
        self.fen.live_states() * self.heads * self.dk * self.dv * 4
    }

    /// Intra-chunk cumulative decays into `ws.g`, head-major `(H, C)`:
    /// `g[h·C + i] = Π_{j≤i} α^h_j` (f64 accumulator per head, matching
    /// the chunkwise reference paths). `alpha` holds either `C` shared
    /// gates — replicated bit-identically per head — or `H·C` head-major
    /// per-head gates.
    fn fill_decays(&self, ws: &mut Workspace, alpha: &[f32]) {
        let (h, c) = (self.heads, self.chunk);
        assert!(
            alpha.len() == c || alpha.len() == h * c,
            "alpha must hold C (shared) or H*C (per-head) gates, got {}",
            alpha.len()
        );
        ws.g.clear();
        for head in 0..alpha.len() / c {
            let mut acc = 1.0f64;
            for &a in &alpha[head * c..(head + 1) * c] {
                acc *= a as f64;
                ws.g.push(acc as f32);
            }
        }
        while ws.g.len() < h * c {
            ws.g.extend_from_within(0..c);
        }
    }

    /// `wscale[h·C + j] = g[h·C + C−1] / g[h·C + j]` — the per-token
    /// write weights for the batched `K^T diag(w) V` kernel, head-major.
    fn fill_wscale(&self, ws: &mut Workspace) {
        let (h, c) = (self.heads, self.chunk);
        ws.wscale.clear();
        for head in 0..h {
            let gh = &ws.g[head * c..(head + 1) * c];
            let cd = gh[c - 1];
            for &gj in gh {
                ws.wscale.push(cd / gj);
            }
        }
    }

    /// Scatter the stacked `(H, C, d_v)` output into the caller's
    /// token-major `(C, H·d_v)` block.
    fn scatter_output(&self, o_stack: &[f32], out: &mut [f32]) {
        let (h, c, dv) = (self.heads, self.chunk, self.dv);
        debug_assert_eq!(o_stack.len(), h * c * dv);
        assert_eq!(out.len(), c * h * dv, "chunk output shape");
        for i in 0..c {
            for head in 0..h {
                out[(i * h + head) * dv..(i * h + head + 1) * dv]
                    .copy_from_slice(&o_stack[(head * c + i) * dv..(head * c + i + 1) * dv]);
            }
        }
    }

    /// Ingest one full chunk for every head under the Mamba-2 (scalar
    /// decay) transition. `ks` is `(H, C, d_k)` and `vs` `(H, C, d_v)`,
    /// head-major row-major; `alpha` the chunk's decay gates — `C`
    /// shared across heads or `H·C` head-major per-head. Pass
    /// [`ChunkOutput`] to also compute the chunk's full per-token outputs
    /// (inter-chunk read over the pre-transition states + the masked
    /// intra-chunk term, in the chunkwise reference's accumulation
    /// order — bit-exact with `loglinear_mamba2::chunkwise` per head).
    pub fn ingest_chunk_mamba2(
        &mut self,
        ws: &mut Workspace,
        ks: &[f32],
        vs: &[f32],
        alpha: &[f32],
        out: Option<ChunkOutput<'_>>,
    ) {
        assert!(!self.finished, "ingest after finish()");
        let (h, c, dk, dv) = (self.heads, self.chunk, self.dk, self.dv);
        assert_eq!(ks.len(), h * c * dk, "ks shape");
        assert_eq!(vs.len(), h * c * dv, "vs shape");
        self.fen.advance(self.z);
        self.fill_decays(ws, alpha);
        if let Some(co) = out {
            assert_eq!(co.qs.len(), h * c * dk, "qs shape");
            let g = std::mem::take(&mut ws.g);
            let mut o_stack = std::mem::take(&mut ws.o_stack);
            o_stack.clear();
            o_stack.resize(h * c * dv, 0.0);
            // inter-chunk first (the reference accumulation order):
            // one batched Q_c S_cat GEMM, λ·cumulative-decay folded
            let lam = co.lambda;
            self.batched_level_read(
                ws,
                co.qs,
                &mut |head, i, lvl| lam(head, i, lvl) * g[head * c + i],
                &mut o_stack,
            );
            // intra-chunk: P = tril(Q K^T) ⊙ decay-ratio ⊙ Λ, then P V
            ws.qk.clear();
            ws.qk.resize(h * c * c, 0.0);
            tensor::gemm_nt_batch_into(h, c, dk, c, co.qs, ks, &mut ws.qk, false);
            for head in 0..h {
                let gh = &g[head * c..(head + 1) * c];
                let p_h = &mut ws.qk[head * c * c..(head + 1) * c * c];
                for i in 0..c {
                    let row = &mut p_h[i * c..(i + 1) * c];
                    for (j, pij) in row.iter_mut().enumerate() {
                        if j > i {
                            *pij = 0.0;
                        } else {
                            *pij *= (gh[i] / gh[j]) * lam(head, i, fenwick::level_of(i, j));
                        }
                    }
                }
                tensor::gemm_sparse_rows(
                    c,
                    c,
                    dv,
                    p_h,
                    &vs[head * c * dv..(head + 1) * c * dv],
                    &mut o_stack[head * c * dv..(head + 1) * c * dv],
                    true,
                );
            }
            self.scatter_output(&o_stack, co.out);
            ws.o_stack = o_stack;
            ws.g = g;
        }
        self.fill_wscale(ws);
        // the new chunk state, all heads in one batched fused kernel
        let mut s_new = self.fen.take_buffer(h * dk, dv);
        tensor::gemm_tn_diag_batch_acc(h, c, dk, dv, &ws.wscale, ks, vs, &mut s_new.data);
        // transition carried states with each head's chunk decay (the
        // chunk sentinel was merged away by the advance above, so only
        // carried buckets remain); elementwise per head-row-range, so a
        // shared schedule reproduces the whole-state scale exactly
        let g = &ws.g;
        self.fen.apply_transition(|s| {
            for head in 0..h {
                let cd = g[head * c + c - 1];
                for x in s.rows_data_mut(head * dk, (head + 1) * dk) {
                    *x *= cd;
                }
            }
        });
        self.fen.set_level0(s_new);
        self.z += 1;
    }

    /// Ingest one full chunk for every head under the Gated-DeltaNet
    /// (gated Householder chain) transition. Shapes as in
    /// [`PrefillEngine::ingest_chunk_mamba2`]; `alpha` and `beta` are the
    /// chunk's decay gates / delta strengths — each either `C` shared
    /// across heads or `H·C` head-major per-head. Pass [`ChunkOutput`]
    /// to also compute the full per-token outputs: the materialized local
    /// UT term (intra-chunk) plus the effective-query level read
    /// (inter-chunk), mirroring `loglinear_gdn::chunkwise` within solver
    /// tolerance.
    pub fn ingest_chunk_gdn(
        &mut self,
        ws: &mut Workspace,
        ks: &[f32],
        vs: &[f32],
        alpha: &[f32],
        beta: &[f32],
        out: Option<ChunkOutput<'_>>,
    ) {
        assert!(!self.finished, "ingest after finish()");
        let (h, c, dk, dv) = (self.heads, self.chunk, self.dk, self.dv);
        assert!(
            beta.len() == c || beta.len() == h * c,
            "beta must hold C (shared) or H*C (per-head) strengths, got {}",
            beta.len()
        );
        assert_eq!(ks.len(), h * c * dk, "ks shape");
        assert_eq!(vs.len(), h * c * dv, "vs shape");
        self.fen.advance(self.z);
        self.fill_decays(ws, alpha);
        let per_head_beta = beta.len() == h * c;
        let b_at = |head: usize, j: usize| if per_head_beta { beta[head * c + j] } else { beta[j] };

        // UT systems for all heads in one batched K_c K_c^T, then the
        // O(C²) scaling pass per head (each head its own β/g schedules):
        // sys_h = I + StrictTril(diag(β^h) (K K^T) ⊙ (g^h_i/g^h_j))
        ws.sys.clear();
        ws.sys.resize(h * c * c, 0.0);
        tensor::gemm_nt_batch_into(h, c, dk, c, ks, ks, &mut ws.sys, false);
        for head in 0..h {
            let gh = &ws.g[head * c..(head + 1) * c];
            let sys_h = &mut ws.sys[head * c * c..(head + 1) * c * c];
            for i in 0..c {
                let (bi, gi) = (b_at(head, i), gh[i]);
                let row = &mut sys_h[i * c..(i + 1) * c];
                for (j, sij) in row.iter_mut().enumerate() {
                    if j < i {
                        *sij *= bi * (gi / gh[j]);
                    } else {
                        *sij = if j == i { 1.0 } else { 0.0 };
                    }
                }
            }
        }

        if let Some(co) = out {
            assert_eq!(co.qs.len(), h * c * dk, "qs shape");
            let g = std::mem::take(&mut ws.g);
            let mut o_stack = std::mem::take(&mut ws.o_stack);
            o_stack.clear();
            o_stack.resize(h * c * dv, 0.0);
            let lam = co.lambda;
            // ---- intra-chunk first (the reference accumulation order):
            // P = (tril(Q K^T) ⊙ Gratio) sys^{-1} diag(β) ⊙ Λ, then P V.
            // The inter-chunk effective queries ride on the SAME solve:
            // with the unmasked P (β folded, Λ not yet),
            // q̂_i = g_i q_i − Σ_{j≤i} P_ij g_j k_j — the UT transform of
            // the gated Householder chain, one GEMM per head instead of
            // an O(C²·d_k) scalar rank-1 sweep per chunk.
            let mut qk = std::mem::take(&mut ws.qk);
            qk.clear();
            qk.resize(h * c * c, 0.0);
            tensor::gemm_nt_batch_into(h, c, dk, c, co.qs, ks, &mut qk, false);
            let mut qe = std::mem::take(&mut ws.qe);
            qe.clear();
            qe.resize(h * c * dk, 0.0);
            let mut kb = std::mem::take(&mut ws.kb);
            kb.clear();
            kb.resize(c * dk, 0.0);
            for head in 0..h {
                let gh = &g[head * c..(head + 1) * c];
                let sys_h = &ws.sys[head * c * c..(head + 1) * c * c];
                let p_h = &mut qk[head * c * c..(head + 1) * c * c];
                for i in 0..c {
                    let row = &mut p_h[i * c..(i + 1) * c];
                    for (j, pij) in row.iter_mut().enumerate() {
                        if j > i {
                            *pij = 0.0;
                        } else {
                            *pij *= gh[i] / gh[j];
                        }
                    }
                }
                // right-solve X · sys = P in place (sys unit lower
                // triangular, so X = P sys^{-1}; columns descending keep
                // X lower triangular)
                for i in 0..c {
                    let row = &mut p_h[i * c..(i + 1) * c];
                    for j in (0..c).rev() {
                        let mut acc = row[j];
                        for l in j + 1..c {
                            let slj = sys_h[l * c + j];
                            if slj != 0.0 {
                                acc -= row[l] * slj;
                            }
                        }
                        row[j] = acc;
                    }
                }
                // fold diag(β) (column scale) → the unmasked local P
                for i in 0..c {
                    let row = &mut p_h[i * c..(i + 1) * c];
                    for j in 0..=i {
                        row[j] *= b_at(head, j);
                    }
                }
                // effective queries from the solve just paid for:
                // q̂ = diag(g) Q + P · (−diag(g) K) as one zero-skipping
                // GEMM over P's lower triangle
                let qe_h = &mut qe[head * c * dk..(head + 1) * c * dk];
                for i in 0..c {
                    let gi = gh[i];
                    let qrow = &co.qs[(head * c + i) * dk..(head * c + i + 1) * dk];
                    for (x, &qv) in qe_h[i * dk..(i + 1) * dk].iter_mut().zip(qrow) {
                        *x = gi * qv;
                    }
                    let w = -gi;
                    let krow = &ks[(head * c + i) * dk..(head * c + i + 1) * dk];
                    for (x, &kv) in kb[i * dk..(i + 1) * dk].iter_mut().zip(krow) {
                        *x = w * kv;
                    }
                }
                tensor::gemm_sparse_rows(c, c, dk, p_h, &kb, qe_h, true);
                // the local Λ mask on top, then P V
                for i in 0..c {
                    let row = &mut p_h[i * c..(i + 1) * c];
                    for j in 0..=i {
                        row[j] *= lam(head, i, fenwick::level_of(i, j));
                    }
                }
                tensor::gemm_sparse_rows(
                    c,
                    c,
                    dv,
                    p_h,
                    &vs[head * c * dv..(head + 1) * c * dv],
                    &mut o_stack[head * c * dv..(head + 1) * c * dv],
                    true,
                );
            }
            ws.qk = qk;
            ws.kb = kb;
            // ---- inter-chunk: one batched Q̂ S_cat read over the
            // UT-transformed effective queries
            self.batched_level_read(ws, &qe, &mut |head, i, lvl| lam(head, i, lvl), &mut o_stack);
            ws.qe = qe;
            self.scatter_output(&o_stack, co.out);
            ws.o_stack = o_stack;
            ws.g = g;
        }

        // Ŵ_h = sys_h^{-1} diag(β^h) V_h by in-place forward substitution
        ws.what.clear();
        ws.what.reserve(h * c * dv);
        for head in 0..h {
            for i in 0..c {
                let v_row = &vs[(head * c + i) * dv..(head * c + i + 1) * dv];
                let bi = b_at(head, i);
                ws.what.extend(v_row.iter().map(|&x| bi * x));
            }
        }
        for head in 0..h {
            let sys_h = &ws.sys[head * c * c..(head + 1) * c * c];
            let wh = &mut ws.what[head * c * dv..(head + 1) * c * dv];
            for i in 1..c {
                let (done, rest) = wh.split_at_mut(i * dv);
                let row_i = &mut rest[..dv];
                for j in 0..i {
                    let coef = sys_h[i * c + j];
                    if coef != 0.0 {
                        tensor::axpy8(row_i, &done[j * dv..(j + 1) * dv], -coef);
                    }
                }
            }
        }

        // S_new_h = K_h^T diag(g^h_C/g^h_s) Ŵ_h, all heads batched
        self.fill_wscale(ws);
        let mut s_new = self.fen.take_buffer(h * dk, dv);
        tensor::gemm_tn_diag_batch_acc(h, c, dk, dv, &ws.wscale, ks, &ws.what, &mut s_new.data);

        // materialize Φ_h = g^h_C · (I − β^h_{C-1} k k^T) ··· (I − β^h_0 k k^T)
        // per head, then advance every carried state with one batched
        // (d_k, d_k) GEMM per level (block-diagonal analogue of
        // ChunkFenwick::apply_matrix_transition, swapping through the
        // stacked scratch)
        ws.phi.clear();
        ws.phi.resize(h * dk * dk, 0.0);
        for head in 0..h {
            let phi_h = &mut ws.phi[head * dk * dk..(head + 1) * dk * dk];
            for i in 0..dk {
                phi_h[i * dk + i] = 1.0;
            }
            for j in 0..c {
                let k_row = &ks[(head * c + j) * dk..(head * c + j + 1) * dk];
                apply_householder_slice(phi_h, dk, k_row, b_at(head, j));
            }
            let g_ch = ws.g[head * c + c - 1];
            for x in phi_h.iter_mut() {
                *x *= g_ch;
            }
        }
        let phi = &ws.phi;
        ws.scratch.resize(h * dk * dv, 0.0);
        let scratch = &mut ws.scratch;
        self.fen.apply_transition(|s| {
            tensor::gemm_batch_into(h, dk, dk, dv, phi, &s.data, scratch, false);
            std::mem::swap(&mut s.data, scratch);
        });

        self.fen.set_level0(s_new);
        self.z += 1;
    }

    /// Head-batched inter-chunk level read: concat each head's live level
    /// states into `S_cat^h (d_k, L·d_v)`, one batched `Q^h @ S_cat^h`
    /// GEMM, then the weight fold into the stacked `(H, C, d_v)` output.
    /// `weight(head, row, token_level)` must already include any
    /// intra-chunk decay factor (per-head, for per-head gate schedules).
    fn batched_level_read(
        &self,
        ws: &mut Workspace,
        qs: &[f32],
        weight: &mut dyn FnMut(usize, usize, usize) -> f32,
        out: &mut [f32],
    ) {
        let (h, c, dk, dv) = (self.heads, self.chunk, self.dk, self.dv);
        assert_eq!(qs.len(), h * c * dk, "qs shape");
        assert_eq!(out.len(), h * c * dv, "out shape");
        ws.active_ids.clear();
        ws.active_ids.extend(self.fen.active().map(|(m, _)| m));
        let nl = ws.active_ids.len();
        if nl == 0 {
            return;
        }
        let ncat = nl * dv;
        ws.cat.clear();
        ws.cat.resize(h * dk * ncat, 0.0);
        for (li, (_, s)) in self.fen.active().enumerate() {
            for head in 0..h {
                for r in 0..dk {
                    let dst = head * dk * ncat + r * ncat + li * dv;
                    ws.cat[dst..dst + dv].copy_from_slice(s.row(head * dk + r));
                }
            }
        }
        ws.read_buf.clear();
        ws.read_buf.resize(h * c * ncat, 0.0);
        tensor::gemm_batch_into(h, c, dk, ncat, qs, &ws.cat, &mut ws.read_buf, false);
        let lc = self.chunk.trailing_zeros() as usize;
        for row in 0..h * c {
            let (head, i) = (row / c, row % c); // head + chunk-local position
            let prow = &ws.read_buf[row * ncat..(row + 1) * ncat];
            let orow = &mut out[row * dv..(row + 1) * dv];
            for (li, &lvl) in ws.active_ids.iter().enumerate() {
                let w = weight(head, i, lc + lvl);
                if w == 0.0 {
                    continue;
                }
                tensor::axpy8(orow, &prow[li * dv..(li + 1) * dv], w);
            }
        }
    }

    /// Seal the engine at the chunk boundary: merge the chunk sentinel
    /// one level up (the merge the *next* chunk would have performed), so
    /// the level layout aligns with the token-granularity post-merge
    /// boundary at `t = chunks · C` and heads can be exported
    /// ([`crate::prefill::bridge::export_prefill_head`]). No further
    /// ingestion is allowed.
    pub fn finish(&mut self) {
        assert!(!self.finished, "finish() called twice");
        self.fen.advance(self.z);
        self.finished = true;
    }

    /// Seed an engine at the post-merge boundary of `z` already-ingested
    /// chunks — the inverse of [`PrefillEngine::export_head`].
    /// `states[h]` is head `h`'s live `(token_level, row-major (d_k, d_v)
    /// state)` list exactly as `export_head` produced it (and as the
    /// prefix cache stores it); the per-head states are restacked into
    /// the shared `(H·d_k, d_v)` hierarchy and ingestion resumes at chunk
    /// `z`: the next `ingest_chunk_*`'s merge is the same no-op a cold
    /// engine performs right after the boundary merge, so a resumed
    /// prefill is **bit-exact** with one that ingested all `z` chunks
    /// itself (the seeded states are byte-faithful copies).
    pub fn from_boundary(
        heads: usize,
        dk: usize,
        dv: usize,
        chunk: usize,
        z: usize,
        states: &[Vec<(usize, &[f32])>],
    ) -> PrefillEngine {
        assert!(heads >= 1 && dk >= 1 && dv >= 1);
        assert!(chunk >= 1 && chunk.is_power_of_two(), "chunk size must be a power of two");
        assert_eq!(states.len(), heads, "one level list per head");
        let lc = chunk.trailing_zeros() as usize;
        for (h, head) in states.iter().enumerate() {
            assert_eq!(
                head.len(),
                z.count_ones() as usize,
                "head {h}: live levels must cover every bucket of the partition of {z} chunks"
            );
        }
        let mut fen = ChunkFenwick::new();
        let (mut rem, mut m) = (z, 1usize);
        while rem != 0 {
            if rem & 1 == 1 {
                let mut s = fen.take_buffer(heads * dk, dv);
                for (h, head) in states.iter().enumerate() {
                    let mut rows = head.iter().filter(|&&(lvl, _)| lvl == lc + m);
                    let &(_, data) = rows.next().unwrap_or_else(|| {
                        panic!(
                            "head {h}: no state at token level {} (boundary of {z} chunks)",
                            lc + m
                        )
                    });
                    assert!(
                        rows.next().is_none(),
                        "head {h}: duplicate token level {}",
                        lc + m
                    );
                    assert_eq!(data.len(), dk * dv, "state shape");
                    s.rows_data_mut(h * dk, (h + 1) * dk).copy_from_slice(data);
                }
                fen.install_level(m, s);
            }
            rem >>= 1;
            m += 1;
        }
        PrefillEngine { heads, dk, dv, chunk, z, finished: false, fen }
    }

    /// One head's live levels as `(token_level, row-major (d_k, d_v)
    /// state)` pairs, ready for
    /// [`crate::state::PooledFenwickState::import_levels`]. Requires
    /// [`PrefillEngine::finish`].
    pub fn export_head(&self, head: usize) -> Vec<(usize, &[f32])> {
        assert!(self.finished, "export before finish()");
        assert!(head < self.heads, "head out of range");
        let lc = self.chunk.trailing_zeros() as usize;
        let dk = self.dk;
        self.fen
            .active()
            .map(|(m, s)| (lc + m, s.rows_data(head * dk, (head + 1) * dk)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;
    use crate::util::Rng;

    /// Per-head single-head oracle: drive a ChunkFenwick with the same
    /// chunk-state writes the Mamba-2 chunkwise path performs, then
    /// advance to the boundary.
    fn mamba2_oracle(ks: &Mat, vs: &Mat, alpha: &[f32], c: usize) -> ChunkFenwick {
        let (t_len, dk, dv) = (ks.rows, ks.cols, vs.cols);
        assert_eq!(t_len % c, 0);
        let mut eng = ChunkFenwick::new();
        let mut wscale = vec![0.0f32; c];
        for z in 0..t_len / c {
            let start = z * c;
            eng.advance(z);
            let mut g = vec![0.0f32; c];
            let mut acc = 1.0f64;
            for i in 0..c {
                acc *= alpha[start + i] as f64;
                g[i] = acc as f32;
            }
            let chunk_decay = g[c - 1];
            for j in 0..c {
                wscale[j] = chunk_decay / g[j];
            }
            let mut w = eng.take_buffer(dk, dv);
            crate::tensor::gemm_tn_diag_acc(
                c,
                dk,
                dv,
                &wscale,
                ks.rows_data(start, start + c),
                vs.rows_data(start, start + c),
                &mut w.data,
            );
            eng.apply_transition(|s| s.scale_inplace(chunk_decay));
            eng.set_level0(w);
        }
        eng.advance(t_len / c);
        eng
    }

    /// Stack H per-head matrices (T, d) into the engine's head-major
    /// per-chunk layout (H, C, d) for chunk z.
    fn stack_chunk(per_head: &[Mat], z: usize, c: usize) -> Vec<f32> {
        let mut out = Vec::new();
        for m in per_head {
            out.extend_from_slice(m.rows_data(z * c, (z + 1) * c));
        }
        out
    }

    #[test]
    fn mamba2_engine_matches_per_head_chunk_fenwick_bit_exact() {
        let mut rng = Rng::new(0x9E1);
        let (heads, dk, dv, c, t_len) = (3usize, 8usize, 6usize, 4usize, 44usize); // 11 chunks
        let ks: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.8, 1.0)).collect();

        let mut ws = Workspace::new();
        let mut eng = PrefillEngine::new(heads, dk, dv, c);
        for z in 0..t_len / c {
            let kc = stack_chunk(&ks, z, c);
            let vc = stack_chunk(&vs, z, c);
            eng.ingest_chunk_mamba2(&mut ws, &kc, &vc, &alpha[z * c..(z + 1) * c], None);
        }
        eng.finish();
        assert_eq!(eng.tokens(), t_len);

        let lc = c.trailing_zeros() as usize;
        for h in 0..heads {
            let oracle = mamba2_oracle(&ks[h], &vs[h], &alpha, c);
            let want: Vec<(usize, &[f32])> =
                oracle.active().map(|(m, s)| (lc + m, &s.data[..])).collect();
            let got = eng.export_head(h);
            assert_eq!(got.len(), want.len(), "head {h}: live level count");
            for ((gl, gs), (wl, ws_)) in got.iter().zip(want.iter()) {
                assert_eq!(gl, wl, "head {h}: level mismatch");
                assert_eq!(*gs, *ws_, "head {h} level {gl}: state not bit-exact");
            }
        }
    }

    /// The per-token output mode against the single-head chunkwise
    /// reference: for shared gates, every chunk's `(C, H·d_v)` output
    /// block must reproduce `loglinear_mamba2::chunkwise` per head —
    /// BIT-EXACT, since both paths run the same GEMM kernels in the same
    /// accumulation order (inter-chunk read, then masked intra-chunk).
    #[test]
    fn mamba2_chunk_outputs_match_chunkwise_reference_bit_exact() {
        let mut rng = Rng::new(0x9E2);
        let (heads, dk, dv, c, t_len) = (2usize, 6usize, 5usize, 8usize, 56usize); // 7 chunks
        let ks: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let qs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.8, 1.0)).collect();
        let nl = crate::fenwick::num_levels(t_len);
        let lambda = Mat::rand_uniform(t_len, nl, 0.05, 1.0, &mut rng);
        let nchunks = t_len / c;

        let mut ws = Workspace::new();
        let mut eng = PrefillEngine::new(heads, dk, dv, c);
        let mut got = vec![vec![0.0f32; c * heads * dv]; nchunks];
        for z in 0..nchunks {
            let kc = stack_chunk(&ks, z, c);
            let vc = stack_chunk(&vs, z, c);
            let qc = stack_chunk(&qs, z, c);
            let start = z * c;
            let lam = |_h: usize, i: usize, lvl: usize| lambda.at(start + i, lvl);
            eng.ingest_chunk_mamba2(
                &mut ws,
                &kc,
                &vc,
                &alpha[start..start + c],
                Some(ChunkOutput { qs: &qc, lambda: &lam, out: &mut got[z][..] }),
            );
        }

        for h in 0..heads {
            let want = crate::attention::loglinear_mamba2::chunkwise(
                &qs[h], &ks[h], &vs[h], &alpha, &lambda, c,
            );
            for z in 0..nchunks {
                for i in 0..c {
                    let grow = &got[z][(i * heads + h) * dv..(i * heads + h + 1) * dv];
                    assert_eq!(
                        grow,
                        want.row(z * c + i),
                        "head {h} chunk {z} token {i}: output not bit-exact"
                    );
                }
            }
        }
    }

    /// GDN per-token outputs against the single-head chunkwise reference:
    /// same algorithm, different (in-place) solver — within tolerance.
    #[test]
    fn gdn_chunk_outputs_match_chunkwise_reference() {
        let mut rng = Rng::new(0x9E5);
        let (heads, dk, dv, c, t_len) = (2usize, 6usize, 5usize, 4usize, 24usize); // 6 chunks
        let ks: Vec<Mat> = (0..heads)
            .map(|_| {
                let mut k = Mat::randn(t_len, dk, 1.0, &mut rng);
                for i in 0..t_len {
                    let n = crate::tensor::ops::l2_norm(k.row(i)).max(1e-6);
                    for x in k.row_mut(i) {
                        *x /= n;
                    }
                }
                k
            })
            .collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let qs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.8, 1.0)).collect();
        let beta: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.1, 0.9)).collect();
        let nl = crate::fenwick::num_levels(t_len);
        let lambda = Mat::rand_uniform(t_len, nl, 0.05, 1.0, &mut rng);
        let nchunks = t_len / c;

        let mut ws = Workspace::new();
        let mut eng = PrefillEngine::new(heads, dk, dv, c);
        let mut got = vec![vec![0.0f32; c * heads * dv]; nchunks];
        for z in 0..nchunks {
            let kc = stack_chunk(&ks, z, c);
            let vc = stack_chunk(&vs, z, c);
            let qc = stack_chunk(&qs, z, c);
            let start = z * c;
            let lam = |_h: usize, i: usize, lvl: usize| lambda.at(start + i, lvl);
            eng.ingest_chunk_gdn(
                &mut ws,
                &kc,
                &vc,
                &alpha[start..start + c],
                &beta[start..start + c],
                Some(ChunkOutput { qs: &qc, lambda: &lam, out: &mut got[z][..] }),
            );
        }

        for h in 0..heads {
            let want = crate::attention::loglinear_gdn::chunkwise(
                &qs[h], &ks[h], &vs[h], &alpha, &beta, &lambda, c,
            );
            for z in 0..nchunks {
                for i in 0..c {
                    let grow = &got[z][(i * heads + h) * dv..(i * heads + h + 1) * dv];
                    for j in 0..dv {
                        let w = want.at(z * c + i, j);
                        assert!(
                            (grow[j] - w).abs() < 2e-3 + 2e-3 * w.abs(),
                            "head {h} chunk {z} token {i} j={j}: {} vs {w}",
                            grow[j]
                        );
                    }
                }
            }
        }
    }

    /// Per-head gate schedules: an H-head engine fed `H·C` head-major
    /// gates must match, per head, a 1-head engine run with that head's
    /// schedule — bit-exact, for both variants — and distinct schedules
    /// must actually change the states.
    #[test]
    fn per_head_gates_match_single_head_engines_and_differ_across_heads() {
        let mut rng = Rng::new(0x9E3);
        let (heads, dk, dv, c, t_len) = (3usize, 6usize, 5usize, 4usize, 24usize); // 6 chunks
        let ks: Vec<Mat> = (0..heads)
            .map(|_| {
                let mut k = Mat::randn(t_len, dk, 1.0, &mut rng);
                for i in 0..t_len {
                    let n = crate::tensor::ops::l2_norm(k.row(i)).max(1e-6);
                    for x in k.row_mut(i) {
                        *x /= n;
                    }
                }
                k
            })
            .collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        // distinct per-head α/β schedules, head-major (H, T)
        let alpha: Vec<Vec<f32>> = (0..heads)
            .map(|h| (0..t_len).map(|_| rng.range_f32(0.7 + 0.05 * h as f32, 1.0)).collect())
            .collect();
        let beta: Vec<Vec<f32>> = (0..heads)
            .map(|_| (0..t_len).map(|_| rng.range_f32(0.1, 1.0)).collect())
            .collect();

        let mut ws = Workspace::new();
        for gdn in [false, true] {
            let mut eng = PrefillEngine::new(heads, dk, dv, c);
            for z in 0..t_len / c {
                let (s, e) = (z * c, (z + 1) * c);
                let kc = stack_chunk(&ks, z, c);
                let vc = stack_chunk(&vs, z, c);
                let mut ac = Vec::new();
                let mut bc = Vec::new();
                for h in 0..heads {
                    ac.extend_from_slice(&alpha[h][s..e]);
                    bc.extend_from_slice(&beta[h][s..e]);
                }
                if gdn {
                    eng.ingest_chunk_gdn(&mut ws, &kc, &vc, &ac, &bc, None);
                } else {
                    eng.ingest_chunk_mamba2(&mut ws, &kc, &vc, &ac, None);
                }
            }
            eng.finish();

            for h in 0..heads {
                let mut solo = PrefillEngine::new(1, dk, dv, c);
                for z in 0..t_len / c {
                    let (s, e) = (z * c, (z + 1) * c);
                    if gdn {
                        solo.ingest_chunk_gdn(
                            &mut ws,
                            ks[h].rows_data(s, e),
                            vs[h].rows_data(s, e),
                            &alpha[h][s..e],
                            &beta[h][s..e],
                            None,
                        );
                    } else {
                        solo.ingest_chunk_mamba2(
                            &mut ws,
                            ks[h].rows_data(s, e),
                            vs[h].rows_data(s, e),
                            &alpha[h][s..e],
                            None,
                        );
                    }
                }
                solo.finish();
                let got = eng.export_head(h);
                let want = solo.export_head(0);
                assert_eq!(got.len(), want.len(), "gdn={gdn} head {h}: live level count");
                for ((gl, gs), (wl, ws_)) in got.iter().zip(want.iter()) {
                    assert_eq!(gl, wl, "gdn={gdn} head {h}: level mismatch");
                    assert_eq!(*gs, *ws_, "gdn={gdn} head {h} level {gl}: not bit-exact");
                }
            }
            // distinct schedules must actually distinguish the heads: run
            // head 1's inputs under head 0's schedule and require a
            // different state (guards against a head index being dropped)
            let mut cross = PrefillEngine::new(1, dk, dv, c);
            for z in 0..t_len / c {
                let (s, e) = (z * c, (z + 1) * c);
                if gdn {
                    cross.ingest_chunk_gdn(
                        &mut ws,
                        ks[1].rows_data(s, e),
                        vs[1].rows_data(s, e),
                        &alpha[0][s..e],
                        &beta[0][s..e],
                        None,
                    );
                } else {
                    cross.ingest_chunk_mamba2(
                        &mut ws,
                        ks[1].rows_data(s, e),
                        vs[1].rows_data(s, e),
                        &alpha[0][s..e],
                        None,
                    );
                }
            }
            cross.finish();
            let h1 = eng.export_head(1);
            let x0 = cross.export_head(0);
            assert!(
                h1.iter().zip(x0.iter()).any(|((_, a), (_, b))| a != b),
                "gdn={gdn}: distinct per-head schedules must change the states"
            );
        }
    }

    /// A shared `C`-gate schedule and the same schedule replicated `H·C`
    /// head-major must be bit-identical (the shared path IS the per-head
    /// path with replication, so pre-per-head results are reproduced
    /// exactly).
    #[test]
    fn shared_gates_equal_replicated_per_head_gates_bit_exact() {
        let mut rng = Rng::new(0x9E4);
        let (heads, dk, dv, c, t_len) = (2usize, 5usize, 4usize, 4usize, 16usize);
        let ks: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.8, 1.0)).collect();
        let beta: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.1, 1.0)).collect();
        let mut ws = Workspace::new();
        for gdn in [false, true] {
            let mut shared = PrefillEngine::new(heads, dk, dv, c);
            let mut repl = PrefillEngine::new(heads, dk, dv, c);
            for z in 0..t_len / c {
                let (s, e) = (z * c, (z + 1) * c);
                let kc = stack_chunk(&ks, z, c);
                let vc = stack_chunk(&vs, z, c);
                let ac: Vec<f32> = (0..heads).flat_map(|_| alpha[s..e].to_vec()).collect();
                let bc: Vec<f32> = (0..heads).flat_map(|_| beta[s..e].to_vec()).collect();
                if gdn {
                    shared.ingest_chunk_gdn(&mut ws, &kc, &vc, &alpha[s..e], &beta[s..e], None);
                    repl.ingest_chunk_gdn(&mut ws, &kc, &vc, &ac, &bc, None);
                } else {
                    shared.ingest_chunk_mamba2(&mut ws, &kc, &vc, &alpha[s..e], None);
                    repl.ingest_chunk_mamba2(&mut ws, &kc, &vc, &ac, None);
                }
            }
            shared.finish();
            repl.finish();
            for h in 0..heads {
                assert_eq!(
                    shared.export_head(h),
                    repl.export_head(h),
                    "gdn={gdn} head {h}: shared vs replicated gates diverged"
                );
            }
        }
    }

    /// The shared-workspace contract: a workspace carried dirty across
    /// engines and variants must produce bit-identical states and outputs
    /// to fresh per-call workspaces. Two engines interleave chunks over
    /// ONE workspace (the serving pattern: many sequences, one scratch
    /// pool) against a run with a fresh workspace per ingest.
    #[test]
    fn shared_workspace_is_bit_identical_to_fresh_workspaces() {
        let mut rng = Rng::new(0x9E6);
        let (heads, dk, dv, c, t_len) = (2usize, 5usize, 4usize, 4usize, 16usize);
        let mk = |rng: &mut Rng| {
            let mut k = Mat::randn(t_len, dk, 1.0, rng);
            for i in 0..t_len {
                let n = crate::tensor::ops::l2_norm(k.row(i)).max(1e-6);
                for x in k.row_mut(i) {
                    *x /= n;
                }
            }
            k
        };
        let ks: Vec<Mat> = (0..2 * heads).map(|_| mk(&mut rng)).collect();
        let vs: Vec<Mat> = (0..2 * heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let qs: Vec<Mat> = (0..2 * heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.8, 1.0)).collect();
        let beta: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.1, 0.9)).collect();
        let nl = crate::fenwick::num_levels(t_len);
        let lambda = Mat::rand_uniform(t_len, nl, 0.05, 1.0, &mut rng);

        // run sequence `which` (0: mamba2 heads 0..H, 1: gdn heads H..2H)
        // over `ws`, returning outputs; `engines` indexed by `which`
        let run_chunk = |eng: &mut PrefillEngine,
                         ws: &mut Workspace,
                         which: usize,
                         z: usize,
                         out: &mut [f32]| {
            let heads_mats = |ms: &[Mat]| {
                let mut v = Vec::new();
                for m in &ms[which * heads..(which + 1) * heads] {
                    v.extend_from_slice(m.rows_data(z * c, (z + 1) * c));
                }
                v
            };
            let (kc, vc, qc) = (heads_mats(&ks), heads_mats(&vs), heads_mats(&qs));
            let start = z * c;
            let lam = |_h: usize, i: usize, lvl: usize| lambda.at(start + i, lvl);
            let co = ChunkOutput { qs: &qc, lambda: &lam, out };
            if which == 1 {
                eng.ingest_chunk_gdn(
                    ws,
                    &kc,
                    &vc,
                    &alpha[start..start + c],
                    &beta[start..start + c],
                    Some(co),
                );
            } else {
                eng.ingest_chunk_mamba2(ws, &kc, &vc, &alpha[start..start + c], Some(co));
            }
        };

        // interleaved over one shared workspace
        let mut shared_ws = Workspace::new();
        let mut engs = [PrefillEngine::new(heads, dk, dv, c), PrefillEngine::new(heads, dk, dv, c)];
        let mut got = vec![vec![vec![0.0f32; c * heads * dv]; t_len / c]; 2];
        for z in 0..t_len / c {
            for which in [0usize, 1] {
                run_chunk(&mut engs[which], &mut shared_ws, which, z, &mut got[which][z]);
            }
        }
        // fresh workspace per ingest
        let mut engs2 =
            [PrefillEngine::new(heads, dk, dv, c), PrefillEngine::new(heads, dk, dv, c)];
        let mut want = vec![vec![vec![0.0f32; c * heads * dv]; t_len / c]; 2];
        for z in 0..t_len / c {
            for which in [0usize, 1] {
                let mut fresh = Workspace::new();
                run_chunk(&mut engs2[which], &mut fresh, which, z, &mut want[which][z]);
            }
        }
        assert_eq!(got, want, "shared workspace changed results");
        for which in [0usize, 1] {
            engs[which].finish();
            engs2[which].finish();
            for h in 0..heads {
                assert_eq!(
                    engs[which].export_head(h),
                    engs2[which].export_head(h),
                    "which={which} head {h}: states diverged under shared workspace"
                );
            }
        }
    }

    /// Boundary seeding ([`PrefillEngine::from_boundary`]) resumes a
    /// chunkwise prefill BIT-EXACTLY: states exported at an intermediate
    /// boundary and re-imported produce the same final states and the
    /// same per-token chunk outputs as the engine that ingested every
    /// chunk itself — the prefix-cache-hit resume contract, both
    /// variants.
    #[test]
    fn seeded_engine_resumes_prefill_bit_exact_with_cold_engine() {
        let mut rng = Rng::new(0x9E7);
        let (heads, dk, dv, c, t_len) = (2usize, 6usize, 5usize, 4usize, 40usize); // 10 chunks
        let split = 6usize; // resume at chunk 6 (binary 110: two live levels)
        let nchunks = t_len / c;
        let ks: Vec<Mat> = (0..heads)
            .map(|_| {
                let mut k = Mat::randn(t_len, dk, 1.0, &mut rng);
                for i in 0..t_len {
                    let n = crate::tensor::ops::l2_norm(k.row(i)).max(1e-6);
                    for x in k.row_mut(i) {
                        *x /= n;
                    }
                }
                k
            })
            .collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let qs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.8, 1.0)).collect();
        let beta: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.1, 0.9)).collect();
        let nl = crate::fenwick::num_levels(t_len);
        let lambda = Mat::rand_uniform(t_len, nl, 0.05, 1.0, &mut rng);

        let mut ws = Workspace::new();
        for gdn in [false, true] {
            let ingest = |eng: &mut PrefillEngine,
                          ws: &mut Workspace,
                          z: usize,
                          out: Option<&mut [f32]>| {
                let kc = stack_chunk(&ks, z, c);
                let vc = stack_chunk(&vs, z, c);
                let qc = stack_chunk(&qs, z, c);
                let start = z * c;
                let lam = |_h: usize, i: usize, lvl: usize| lambda.at(start + i, lvl);
                let co = out.map(|o| ChunkOutput { qs: &qc, lambda: &lam, out: o });
                if gdn {
                    eng.ingest_chunk_gdn(ws, &kc, &vc, &alpha[start..start + c], &beta[start..start + c], co);
                } else {
                    eng.ingest_chunk_mamba2(ws, &kc, &vc, &alpha[start..start + c], co);
                }
            };

            // cold: every chunk, outputs captured past the split
            let mut cold = PrefillEngine::new(heads, dk, dv, c);
            let mut cold_out = vec![vec![0.0f32; c * heads * dv]; nchunks - split];
            for z in 0..nchunks {
                let o = if z >= split { Some(&mut cold_out[z - split][..]) } else { None };
                ingest(&mut cold, &mut ws, z, o);
            }
            cold.finish();

            // prefix: chunks 0..split, export at the boundary, reseed
            let mut pre = PrefillEngine::new(heads, dk, dv, c);
            for z in 0..split {
                ingest(&mut pre, &mut ws, z, None);
            }
            pre.finish();
            let exported: Vec<Vec<(usize, &[f32])>> =
                (0..heads).map(|h| pre.export_head(h)).collect();
            let mut resumed = PrefillEngine::from_boundary(heads, dk, dv, c, split, &exported);
            assert_eq!(resumed.tokens(), split * c);
            assert_eq!(resumed.live_states(), split.count_ones() as usize);
            let mut res_out = vec![vec![0.0f32; c * heads * dv]; nchunks - split];
            for z in split..nchunks {
                ingest(&mut resumed, &mut ws, z, Some(&mut res_out[z - split][..]));
            }
            resumed.finish();

            assert_eq!(res_out, cold_out, "gdn={gdn}: resumed chunk outputs not bit-exact");
            for h in 0..heads {
                assert_eq!(
                    resumed.export_head(h),
                    cold.export_head(h),
                    "gdn={gdn} head {h}: resumed states not bit-exact"
                );
            }
        }
    }
}
