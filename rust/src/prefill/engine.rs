//! Head-batched chunkwise prefill engine (state-only Alg. 1).
//!
//! [`PrefillEngine`] ingests a prompt one chunk at a time for **H heads
//! at once**. The level hierarchy itself *is* a
//! [`crate::attention::loglinear::ChunkFenwick`] — no mirrored merge
//! skeleton — holding **stacked** states: level `m` is one
//! `(H·d_k, d_v)` matrix whose rows `h·d_k..(h+1)·d_k` are head `h`'s
//! bucket state. Stacking is what lets every per-chunk product run
//! through the batched GEMM dispatch ([`crate::tensor::batch`]) as one
//! kernel launch covering all heads:
//!
//! - state write `S_new^h = K_c^{hT} diag(w) V_c^h` →
//!   [`crate::tensor::gemm_tn_diag_batch_acc`],
//! - GDN UT system `K_c^h K_c^{hT}` → [`crate::tensor::gemm_nt_batch_into`],
//! - GDN carried-state transition `Φ^h S^h` and the optional level read
//!   `Q_c^h S_cat^h` → [`crate::tensor::gemm_batch_into`].
//!
//! Per head and chunk, the op sequences mirror the single-head chunkwise
//! reference paths (`loglinear_mamba2::chunkwise` /
//! `loglinear_gdn::chunkwise` state halves), so exported per-head states
//! match the per-head engines bit-for-bit on the Mamba-2 path and within
//! solver tolerance on the GDN path (the UT solve here is an in-place
//! forward substitution).
//!
//! The engine is **state-only**: serving prefill never needs prompt
//! logits (the final prompt token is fed through the decode step, which
//! samples the first generated token), so ingestion skips intra-chunk
//! attention and level reads entirely. The head-batched `Q_c S_cat` read
//! is still available via [`LevelRead`] on the Mamba-2 path — the seam
//! for prompt scoring (per-token log-probs) — and covers the inter-chunk
//! contribution only.
//!
//! Gates (`α`, `β`) may be **shared or per-head** (the ROADMAP per-head
//! gate-tables item): ingest accepts either `C` gates applied to every
//! head or `H·C` head-major gates, matching the pooled backend's
//! per-head [`crate::state::GateTable`]. The shared case is executed as
//! the per-head case with the schedule replicated bit-identically, so
//! one code path serves both and a shared schedule reproduces the
//! pre-per-head results exactly (regression-tested below). As predicted,
//! only the bookkeeping changes — every batched GEMM keeps its shape.

use crate::attention::deltanet::apply_householder_slice;
use crate::attention::loglinear::ChunkFenwick;
use crate::tensor::{self, Mat};

/// Optional inter-chunk level read riding along a Mamba-2 ingest: one
/// head-batched `Q_c S_cat` GEMM over the pre-transition level states,
/// λ·decay-folded into `out`.
pub struct LevelRead<'a> {
    /// stacked queries `(H, C, d_k)`, head-major row-major
    pub qs: &'a [f32],
    /// λ lookup `(head, chunk-local row, token level) → weight` (token
    /// level = `log2(C) + chunk level`; the engine folds the intra-chunk
    /// cumulative decay in itself; ignore the head argument for schedules
    /// shared across heads)
    pub lambda: &'a dyn Fn(usize, usize, usize) -> f32,
    /// stacked outputs `(H, C, d_v)`, accumulated into
    pub out: &'a mut [f32],
}

/// Multi-head chunk-granularity Fenwick state builder (see module docs).
#[derive(Debug)]
pub struct PrefillEngine {
    heads: usize,
    dk: usize,
    dv: usize,
    chunk: usize,
    /// chunks ingested so far
    z: usize,
    /// sealed by [`PrefillEngine::finish`]: level 0 merged, exportable
    finished: bool,
    /// the shared chunk-granularity hierarchy, holding stacked
    /// `(H·d_k, d_v)` states (head `h` = rows `h·d_k..(h+1)·d_k`)
    fen: ChunkFenwick,
    /// stacked scratch for the batched `Φ S` transition swap
    scratch: Mat,
    // ---- workspaces (reused across chunks; no steady-state allocation)
    g: Vec<f32>,
    wscale: Vec<f32>,
    cat: Vec<f32>,
    read_buf: Vec<f32>,
    active_ids: Vec<usize>,
    sys: Vec<f32>,
    what: Vec<f32>,
    phi: Vec<f32>,
}

impl PrefillEngine {
    pub fn new(heads: usize, dk: usize, dv: usize, chunk: usize) -> PrefillEngine {
        assert!(heads >= 1 && dk >= 1 && dv >= 1);
        assert!(chunk >= 1 && chunk.is_power_of_two(), "chunk size must be a power of two");
        PrefillEngine {
            heads,
            dk,
            dv,
            chunk,
            z: 0,
            finished: false,
            fen: ChunkFenwick::new(),
            scratch: Mat::zeros(heads * dk, dv),
            g: Vec::new(),
            wscale: Vec::new(),
            cat: Vec::new(),
            read_buf: Vec::new(),
            active_ids: Vec::new(),
            sys: Vec::new(),
            what: Vec::new(),
            phi: Vec::new(),
        }
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    /// State shape per head.
    pub fn state_dims(&self) -> (usize, usize) {
        (self.dk, self.dv)
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk
    }

    /// Chunks ingested so far.
    pub fn chunks(&self) -> usize {
        self.z
    }

    /// Tokens ingested so far (`chunks · chunk_size`).
    pub fn tokens(&self) -> usize {
        self.z * self.chunk
    }

    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Live stacked level states (`popcount(z)` after finish).
    pub fn live_states(&self) -> usize {
        self.fen.live_states()
    }

    /// Resident bytes: live stacked states plus the transition scratch.
    pub fn state_bytes(&self) -> usize {
        (self.fen.live_states() * self.heads * self.dk * self.dv + self.scratch.data.len()) * 4
    }

    /// Intra-chunk cumulative decays, head-major `(H, C)`:
    /// `g[h·C + i] = Π_{j≤i} α^h_j` (f64 accumulator per head, matching
    /// the chunkwise reference paths). `alpha` holds either `C` shared
    /// gates — replicated bit-identically per head — or `H·C` head-major
    /// per-head gates.
    fn fill_decays(&mut self, alpha: &[f32]) {
        let (h, c) = (self.heads, self.chunk);
        assert!(
            alpha.len() == c || alpha.len() == h * c,
            "alpha must hold C (shared) or H*C (per-head) gates, got {}",
            alpha.len()
        );
        self.g.clear();
        for head in 0..alpha.len() / c {
            let mut acc = 1.0f64;
            for &a in &alpha[head * c..(head + 1) * c] {
                acc *= a as f64;
                self.g.push(acc as f32);
            }
        }
        while self.g.len() < h * c {
            self.g.extend_from_within(0..c);
        }
    }

    /// `wscale[h·C + j] = g[h·C + C−1] / g[h·C + j]` — the per-token
    /// write weights for the batched `K^T diag(w) V` kernel, head-major
    /// (each head's chunk decay over its own cumulative decays).
    fn fill_wscale(&mut self) {
        let (h, c) = (self.heads, self.chunk);
        self.wscale.clear();
        for head in 0..h {
            let gh = &self.g[head * c..(head + 1) * c];
            let cd = gh[c - 1];
            for &gj in gh {
                self.wscale.push(cd / gj);
            }
        }
    }

    /// Ingest one full chunk for every head under the Mamba-2 (scalar
    /// decay) transition. `ks` is `(H, C, d_k)` and `vs` `(H, C, d_v)`,
    /// head-major row-major; `alpha` the chunk's decay gates — `C`
    /// shared across heads or `H·C` head-major per-head. Pass
    /// [`LevelRead`] to also read the chunk's inter-chunk contribution
    /// (one head-batched `Q_c S_cat` GEMM over the pre-transition
    /// states).
    pub fn ingest_chunk_mamba2(
        &mut self,
        ks: &[f32],
        vs: &[f32],
        alpha: &[f32],
        read: Option<LevelRead<'_>>,
    ) {
        assert!(!self.finished, "ingest after finish()");
        let (h, c, dk, dv) = (self.heads, self.chunk, self.dk, self.dv);
        assert_eq!(ks.len(), h * c * dk, "ks shape");
        assert_eq!(vs.len(), h * c * dv, "vs shape");
        self.fen.advance(self.z);
        self.fill_decays(alpha);
        if let Some(rd) = read {
            let g = std::mem::take(&mut self.g);
            let lam = rd.lambda;
            self.batched_level_read(
                rd.qs,
                &mut |head, i, lvl| lam(head, i, lvl) * g[head * c + i],
                rd.out,
            );
            self.g = g;
        }
        self.fill_wscale();
        // the new chunk state, all heads in one batched fused kernel
        let mut s_new = self.fen.take_buffer(h * dk, dv);
        tensor::gemm_tn_diag_batch_acc(h, c, dk, dv, &self.wscale, ks, vs, &mut s_new.data);
        // transition carried states with each head's chunk decay (the
        // chunk sentinel was merged away by the advance above, so only
        // carried buckets remain); elementwise per head-row-range, so a
        // shared schedule reproduces the old whole-state scale exactly
        let g = &self.g;
        self.fen.apply_transition(|s| {
            for head in 0..h {
                let cd = g[head * c + c - 1];
                for x in s.rows_data_mut(head * dk, (head + 1) * dk) {
                    *x *= cd;
                }
            }
        });
        self.fen.set_level0(s_new);
        self.z += 1;
    }

    /// Ingest one full chunk for every head under the Gated-DeltaNet
    /// (gated Householder chain) transition. Shapes as in
    /// [`PrefillEngine::ingest_chunk_mamba2`]; `alpha` and `beta` are the
    /// chunk's decay gates / delta strengths — each either `C` shared
    /// across heads or `H·C` head-major per-head. State-only (no read
    /// seam: GDN reads need the effective-query chain, which serving
    /// prefill never exercises).
    pub fn ingest_chunk_gdn(&mut self, ks: &[f32], vs: &[f32], alpha: &[f32], beta: &[f32]) {
        assert!(!self.finished, "ingest after finish()");
        let (h, c, dk, dv) = (self.heads, self.chunk, self.dk, self.dv);
        assert!(
            beta.len() == c || beta.len() == h * c,
            "beta must hold C (shared) or H*C (per-head) strengths, got {}",
            beta.len()
        );
        assert_eq!(ks.len(), h * c * dk, "ks shape");
        assert_eq!(vs.len(), h * c * dv, "vs shape");
        self.fen.advance(self.z);
        self.fill_decays(alpha);
        let per_head_beta = beta.len() == h * c;
        let b_at = |head: usize, j: usize| if per_head_beta { beta[head * c + j] } else { beta[j] };

        // UT systems for all heads in one batched K_c K_c^T, then the
        // O(C²) scaling pass per head (each head its own β/g schedules):
        // sys_h = I + StrictTril(diag(β^h) (K K^T) ⊙ (g^h_i/g^h_j))
        self.sys.clear();
        self.sys.resize(h * c * c, 0.0);
        tensor::gemm_nt_batch_into(h, c, dk, c, ks, ks, &mut self.sys, false);
        for head in 0..h {
            let gh = &self.g[head * c..(head + 1) * c];
            let sys_h = &mut self.sys[head * c * c..(head + 1) * c * c];
            for i in 0..c {
                let (bi, gi) = (b_at(head, i), gh[i]);
                let row = &mut sys_h[i * c..(i + 1) * c];
                for (j, sij) in row.iter_mut().enumerate() {
                    if j < i {
                        *sij *= bi * (gi / gh[j]);
                    } else {
                        *sij = if j == i { 1.0 } else { 0.0 };
                    }
                }
            }
        }

        // Ŵ_h = sys_h^{-1} diag(β^h) V_h by in-place forward substitution
        self.what.clear();
        self.what.reserve(h * c * dv);
        for head in 0..h {
            for i in 0..c {
                let v_row = &vs[(head * c + i) * dv..(head * c + i + 1) * dv];
                let bi = b_at(head, i);
                self.what.extend(v_row.iter().map(|&x| bi * x));
            }
        }
        for head in 0..h {
            let sys_h = &self.sys[head * c * c..(head + 1) * c * c];
            let wh = &mut self.what[head * c * dv..(head + 1) * c * dv];
            for i in 1..c {
                let (done, rest) = wh.split_at_mut(i * dv);
                let row_i = &mut rest[..dv];
                for j in 0..i {
                    let coef = sys_h[i * c + j];
                    if coef != 0.0 {
                        tensor::axpy8(row_i, &done[j * dv..(j + 1) * dv], -coef);
                    }
                }
            }
        }

        // S_new_h = K_h^T diag(g^h_C/g^h_s) Ŵ_h, all heads batched
        self.fill_wscale();
        let mut s_new = self.fen.take_buffer(h * dk, dv);
        tensor::gemm_tn_diag_batch_acc(h, c, dk, dv, &self.wscale, ks, &self.what, &mut s_new.data);

        // materialize Φ_h = g^h_C · (I − β^h_{C-1} k k^T) ··· (I − β^h_0 k k^T)
        // per head, then advance every carried state with one batched
        // (d_k, d_k) GEMM per level (block-diagonal analogue of
        // ChunkFenwick::apply_matrix_transition, swapping through the
        // stacked scratch)
        self.phi.clear();
        self.phi.resize(h * dk * dk, 0.0);
        for head in 0..h {
            let phi_h = &mut self.phi[head * dk * dk..(head + 1) * dk * dk];
            for i in 0..dk {
                phi_h[i * dk + i] = 1.0;
            }
            for j in 0..c {
                let k_row = &ks[(head * c + j) * dk..(head * c + j + 1) * dk];
                apply_householder_slice(phi_h, dk, k_row, b_at(head, j));
            }
            let g_ch = self.g[head * c + c - 1];
            for x in phi_h.iter_mut() {
                *x *= g_ch;
            }
        }
        let phi = &self.phi;
        let scratch = &mut self.scratch;
        self.fen.apply_transition(|s| {
            tensor::gemm_batch_into(h, dk, dk, dv, phi, &s.data, &mut scratch.data, false);
            std::mem::swap(&mut s.data, &mut scratch.data);
        });

        self.fen.set_level0(s_new);
        self.z += 1;
    }

    /// Head-batched inter-chunk level read: concat each head's live level
    /// states into `S_cat^h (d_k, L·d_v)`, one batched `Q^h @ S_cat^h`
    /// GEMM, then the weight fold. `weight(head, row, token_level)` must
    /// already include any intra-chunk decay factor (per-head, for
    /// per-head gate schedules).
    fn batched_level_read(
        &mut self,
        qs: &[f32],
        weight: &mut dyn FnMut(usize, usize, usize) -> f32,
        out: &mut [f32],
    ) {
        let (h, c, dk, dv) = (self.heads, self.chunk, self.dk, self.dv);
        assert_eq!(qs.len(), h * c * dk, "qs shape");
        assert_eq!(out.len(), h * c * dv, "out shape");
        self.active_ids.clear();
        self.active_ids.extend(self.fen.active().map(|(m, _)| m));
        let nl = self.active_ids.len();
        if nl == 0 {
            return;
        }
        let ncat = nl * dv;
        self.cat.clear();
        self.cat.resize(h * dk * ncat, 0.0);
        for (li, (_, s)) in self.fen.active().enumerate() {
            for head in 0..h {
                for r in 0..dk {
                    let dst = head * dk * ncat + r * ncat + li * dv;
                    self.cat[dst..dst + dv].copy_from_slice(s.row(head * dk + r));
                }
            }
        }
        self.read_buf.clear();
        self.read_buf.resize(h * c * ncat, 0.0);
        tensor::gemm_batch_into(h, c, dk, ncat, qs, &self.cat, &mut self.read_buf, false);
        let lc = self.chunk.trailing_zeros() as usize;
        for row in 0..h * c {
            let (head, i) = (row / c, row % c); // head + chunk-local position
            let prow = &self.read_buf[row * ncat..(row + 1) * ncat];
            let orow = &mut out[row * dv..(row + 1) * dv];
            for (li, &lvl) in self.active_ids.iter().enumerate() {
                let w = weight(head, i, lc + lvl);
                if w == 0.0 {
                    continue;
                }
                tensor::axpy8(orow, &prow[li * dv..(li + 1) * dv], w);
            }
        }
    }

    /// Seal the engine at the chunk boundary: merge the chunk sentinel
    /// one level up (the merge the *next* chunk would have performed), so
    /// the level layout aligns with the token-granularity post-merge
    /// boundary at `t = chunks · C` and heads can be exported
    /// ([`crate::prefill::bridge::export_prefill_head`]). No further
    /// ingestion is allowed.
    pub fn finish(&mut self) {
        assert!(!self.finished, "finish() called twice");
        self.fen.advance(self.z);
        self.finished = true;
    }

    /// One head's live levels as `(token_level, row-major (d_k, d_v)
    /// state)` pairs, ready for
    /// [`crate::state::PooledFenwickState::import_levels`]. Requires
    /// [`PrefillEngine::finish`].
    pub fn export_head(&self, head: usize) -> Vec<(usize, &[f32])> {
        assert!(self.finished, "export before finish()");
        assert!(head < self.heads, "head out of range");
        let lc = self.chunk.trailing_zeros() as usize;
        let dk = self.dk;
        self.fen
            .active()
            .map(|(m, s)| (lc + m, s.rows_data(head * dk, (head + 1) * dk)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Per-head single-head oracle: drive a ChunkFenwick with the same
    /// chunk-state writes the Mamba-2 chunkwise path performs, then
    /// advance to the boundary.
    fn mamba2_oracle(ks: &Mat, vs: &Mat, alpha: &[f32], c: usize) -> ChunkFenwick {
        let (t_len, dk, dv) = (ks.rows, ks.cols, vs.cols);
        assert_eq!(t_len % c, 0);
        let mut eng = ChunkFenwick::new();
        let mut wscale = vec![0.0f32; c];
        for z in 0..t_len / c {
            let start = z * c;
            eng.advance(z);
            let mut g = vec![0.0f32; c];
            let mut acc = 1.0f64;
            for i in 0..c {
                acc *= alpha[start + i] as f64;
                g[i] = acc as f32;
            }
            let chunk_decay = g[c - 1];
            for j in 0..c {
                wscale[j] = chunk_decay / g[j];
            }
            let mut w = eng.take_buffer(dk, dv);
            crate::tensor::gemm_tn_diag_acc(
                c,
                dk,
                dv,
                &wscale,
                ks.rows_data(start, start + c),
                vs.rows_data(start, start + c),
                &mut w.data,
            );
            eng.apply_transition(|s| s.scale_inplace(chunk_decay));
            eng.set_level0(w);
        }
        eng.advance(t_len / c);
        eng
    }

    /// Stack H per-head matrices (T, d) into the engine's head-major
    /// per-chunk layout (H, C, d) for chunk z.
    fn stack_chunk(per_head: &[Mat], z: usize, c: usize) -> Vec<f32> {
        let mut out = Vec::new();
        for m in per_head {
            out.extend_from_slice(m.rows_data(z * c, (z + 1) * c));
        }
        out
    }

    #[test]
    fn mamba2_engine_matches_per_head_chunk_fenwick_bit_exact() {
        let mut rng = Rng::new(0x9E1);
        let (heads, dk, dv, c, t_len) = (3usize, 8usize, 6usize, 4usize, 44usize); // 11 chunks
        let ks: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.8, 1.0)).collect();

        let mut eng = PrefillEngine::new(heads, dk, dv, c);
        for z in 0..t_len / c {
            let kc = stack_chunk(&ks, z, c);
            let vc = stack_chunk(&vs, z, c);
            eng.ingest_chunk_mamba2(&kc, &vc, &alpha[z * c..(z + 1) * c], None);
        }
        eng.finish();
        assert_eq!(eng.tokens(), t_len);

        let lc = c.trailing_zeros() as usize;
        for h in 0..heads {
            let oracle = mamba2_oracle(&ks[h], &vs[h], &alpha, c);
            let want: Vec<(usize, &[f32])> =
                oracle.active().map(|(m, s)| (lc + m, &s.data[..])).collect();
            let got = eng.export_head(h);
            assert_eq!(got.len(), want.len(), "head {h}: live level count");
            for ((gl, gs), (wl, ws)) in got.iter().zip(want.iter()) {
                assert_eq!(gl, wl, "head {h}: level mismatch");
                assert_eq!(*gs, *ws, "head {h} level {gl}: state not bit-exact");
            }
        }
    }

    #[test]
    fn level_read_matches_per_head_chunk_fenwick_read() {
        // The head-batched Q_c S_cat read against the single-head
        // ChunkFenwick read, same λ·decay weights: bit-exact.
        let mut rng = Rng::new(0x9E2);
        let (heads, dk, dv, c, t_len) = (2usize, 6usize, 5usize, 8usize, 56usize); // 7 chunks
        let ks: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let qs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.8, 1.0)).collect();
        let nl = crate::fenwick::num_levels(t_len);
        let lambda = Mat::rand_uniform(t_len, nl, 0.05, 1.0, &mut rng);
        let lc = c.trailing_zeros() as usize;
        let nchunks = t_len / c;

        // engine with reads on every chunk
        let mut eng = PrefillEngine::new(heads, dk, dv, c);
        let mut got = vec![vec![0.0f32; heads * c * dv]; nchunks];
        for z in 0..nchunks {
            let kc = stack_chunk(&ks, z, c);
            let vc = stack_chunk(&vs, z, c);
            let qc = stack_chunk(&qs, z, c);
            let start = z * c;
            let lam = |_h: usize, i: usize, lvl: usize| lambda.at(start + i, lvl);
            eng.ingest_chunk_mamba2(
                &kc,
                &vc,
                &alpha[start..start + c],
                Some(LevelRead { qs: &qc, lambda: &lam, out: &mut got[z][..] }),
            );
        }

        // per-head oracle: ChunkFenwick::read_levels_into per chunk
        for h in 0..heads {
            let mut oracle = ChunkFenwick::new();
            let mut wscale = vec![0.0f32; c];
            for z in 0..nchunks {
                let start = z * c;
                oracle.advance(z);
                let mut g = vec![0.0f32; c];
                let mut acc = 1.0f64;
                for i in 0..c {
                    acc *= alpha[start + i] as f64;
                    g[i] = acc as f32;
                }
                let mut want = Mat::zeros(c, dv);
                oracle.read_levels_into(qs[h].rows_data(start, start + c), c, &mut want, 0, |i, m| {
                    lambda.at(start + i, lc + m) * g[i]
                });
                let got_h = &got[z][h * c * dv..(h + 1) * c * dv];
                assert_eq!(got_h, &want.data[..], "head {h} chunk {z}: read not bit-exact");
                // mirror the engine's write/transition to keep states in step
                let chunk_decay = g[c - 1];
                for j in 0..c {
                    wscale[j] = chunk_decay / g[j];
                }
                let mut w = oracle.take_buffer(dk, dv);
                crate::tensor::gemm_tn_diag_acc(
                    c,
                    dk,
                    dv,
                    &wscale,
                    ks[h].rows_data(start, start + c),
                    vs[h].rows_data(start, start + c),
                    &mut w.data,
                );
                oracle.apply_transition(|s| s.scale_inplace(chunk_decay));
                oracle.set_level0(w);
            }
        }
    }

    /// Per-head gate schedules (ROADMAP per-head gate-tables item): an
    /// H-head engine fed `H·C` head-major gates must match, per head, a
    /// 1-head engine run with that head's schedule — bit-exact, for both
    /// variants — and distinct schedules must actually change the states.
    #[test]
    fn per_head_gates_match_single_head_engines_and_differ_across_heads() {
        let mut rng = Rng::new(0x9E3);
        let (heads, dk, dv, c, t_len) = (3usize, 6usize, 5usize, 4usize, 24usize); // 6 chunks
        let ks: Vec<Mat> = (0..heads)
            .map(|_| {
                let mut k = Mat::randn(t_len, dk, 1.0, &mut rng);
                for i in 0..t_len {
                    let n = crate::tensor::ops::l2_norm(k.row(i)).max(1e-6);
                    for x in k.row_mut(i) {
                        *x /= n;
                    }
                }
                k
            })
            .collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        // distinct per-head α/β schedules, head-major (H, T)
        let alpha: Vec<Vec<f32>> = (0..heads)
            .map(|h| (0..t_len).map(|_| rng.range_f32(0.7 + 0.05 * h as f32, 1.0)).collect())
            .collect();
        let beta: Vec<Vec<f32>> = (0..heads)
            .map(|_| (0..t_len).map(|_| rng.range_f32(0.1, 1.0)).collect())
            .collect();

        for gdn in [false, true] {
            let mut eng = PrefillEngine::new(heads, dk, dv, c);
            for z in 0..t_len / c {
                let (s, e) = (z * c, (z + 1) * c);
                let kc = stack_chunk(&ks, z, c);
                let vc = stack_chunk(&vs, z, c);
                let mut ac = Vec::new();
                let mut bc = Vec::new();
                for h in 0..heads {
                    ac.extend_from_slice(&alpha[h][s..e]);
                    bc.extend_from_slice(&beta[h][s..e]);
                }
                if gdn {
                    eng.ingest_chunk_gdn(&kc, &vc, &ac, &bc);
                } else {
                    eng.ingest_chunk_mamba2(&kc, &vc, &ac, None);
                }
            }
            eng.finish();

            for h in 0..heads {
                let mut solo = PrefillEngine::new(1, dk, dv, c);
                for z in 0..t_len / c {
                    let (s, e) = (z * c, (z + 1) * c);
                    if gdn {
                        solo.ingest_chunk_gdn(
                            ks[h].rows_data(s, e),
                            vs[h].rows_data(s, e),
                            &alpha[h][s..e],
                            &beta[h][s..e],
                        );
                    } else {
                        solo.ingest_chunk_mamba2(
                            ks[h].rows_data(s, e),
                            vs[h].rows_data(s, e),
                            &alpha[h][s..e],
                            None,
                        );
                    }
                }
                solo.finish();
                let got = eng.export_head(h);
                let want = solo.export_head(0);
                assert_eq!(got.len(), want.len(), "gdn={gdn} head {h}: live level count");
                for ((gl, gs), (wl, ws)) in got.iter().zip(want.iter()) {
                    assert_eq!(gl, wl, "gdn={gdn} head {h}: level mismatch");
                    assert_eq!(*gs, *ws, "gdn={gdn} head {h} level {gl}: not bit-exact");
                }
            }
            // distinct schedules must actually distinguish the heads: run
            // head 1's inputs under head 0's schedule and require a
            // different state (guards against a head index being dropped)
            let mut cross = PrefillEngine::new(1, dk, dv, c);
            for z in 0..t_len / c {
                let (s, e) = (z * c, (z + 1) * c);
                if gdn {
                    cross.ingest_chunk_gdn(
                        ks[1].rows_data(s, e),
                        vs[1].rows_data(s, e),
                        &alpha[0][s..e],
                        &beta[0][s..e],
                    );
                } else {
                    cross.ingest_chunk_mamba2(
                        ks[1].rows_data(s, e),
                        vs[1].rows_data(s, e),
                        &alpha[0][s..e],
                        None,
                    );
                }
            }
            cross.finish();
            let h1 = eng.export_head(1);
            let x0 = cross.export_head(0);
            assert!(
                h1.iter().zip(x0.iter()).any(|((_, a), (_, b))| a != b),
                "gdn={gdn}: distinct per-head schedules must change the states"
            );
        }
    }

    /// A shared `C`-gate schedule and the same schedule replicated `H·C`
    /// head-major must be bit-identical (the shared path IS the per-head
    /// path with replication, so pre-per-head results are reproduced
    /// exactly).
    #[test]
    fn shared_gates_equal_replicated_per_head_gates_bit_exact() {
        let mut rng = Rng::new(0x9E4);
        let (heads, dk, dv, c, t_len) = (2usize, 5usize, 4usize, 4usize, 16usize);
        let ks: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let alpha: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.8, 1.0)).collect();
        let beta: Vec<f32> = (0..t_len).map(|_| rng.range_f32(0.1, 1.0)).collect();
        for gdn in [false, true] {
            let mut shared = PrefillEngine::new(heads, dk, dv, c);
            let mut repl = PrefillEngine::new(heads, dk, dv, c);
            for z in 0..t_len / c {
                let (s, e) = (z * c, (z + 1) * c);
                let kc = stack_chunk(&ks, z, c);
                let vc = stack_chunk(&vs, z, c);
                let ac: Vec<f32> = (0..heads).flat_map(|_| alpha[s..e].to_vec()).collect();
                let bc: Vec<f32> = (0..heads).flat_map(|_| beta[s..e].to_vec()).collect();
                if gdn {
                    shared.ingest_chunk_gdn(&kc, &vc, &alpha[s..e], &beta[s..e]);
                    repl.ingest_chunk_gdn(&kc, &vc, &ac, &bc);
                } else {
                    shared.ingest_chunk_mamba2(&kc, &vc, &alpha[s..e], None);
                    repl.ingest_chunk_mamba2(&kc, &vc, &ac, None);
                }
            }
            shared.finish();
            repl.finish();
            for h in 0..heads {
                assert_eq!(
                    shared.export_head(h),
                    repl.export_head(h),
                    "gdn={gdn} head {h}: shared vs replicated gates diverged"
                );
            }
        }
    }
}
