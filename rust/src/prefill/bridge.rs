//! The state-export bridge: chunk-granularity Fenwick hierarchies →
//! pool-backed token-granularity decode states.
//!
//! Why this is exact (and not an approximation): after `z` chunks of size
//! `C = 2^lc`, a chunk-level bucket `m ≥ 1` summarizes chunks
//! `[b − 2^{m-1}, b)` — exactly the tokens of the token-level `lc + m`
//! bucket in the Fenwick partition of `t = z·C`. And at the *post-merge
//! boundary* of token step `t` (the merge of step `t` performed, the
//! sentinel not yet written), the token machine's live levels are exactly
//! `{l + 1 : bit l of t set}` = `{lc + m : bit (m−1) of z set}` — the
//! chunk hierarchy's live levels after
//! [`ChunkFenwick::advance`]`(z)`, relabeled. So export is: merge the
//! chunk sentinel (`advance(z)` / [`PrefillEngine::finish`]), copy each
//! live chunk-level state into a pool block at token level `lc + m`, set
//! `t = z·C`. The next [`PooledFenwickState::advance`] performs a no-op
//! merge (all levels `≤ lssb(t)` are empty) and proceeds exactly like the
//! token recurrence — no special decode-side casing.
//!
//! Decay bookkeeping also lines up: the chunkwise engines apply each
//! chunk's transition to carried states at the end of the chunk, so an
//! exported state carries transitions through token `t − 1`, which is
//! what the token machine's state holds between steps `t − 1` and `t`.
//!
//! Content equality is within the chunkwise tolerance (the chunk state
//! write reorders the same sum of decayed outer products into GEMMs);
//! layout equality is asserted hard by
//! [`PooledFenwickState::import_levels`]. The tests below prove the
//! acceptance property: a sequence prefilled through the bridge, then
//! decoded token-by-token, matches the [`FenwickState`]
//! (`crate::state::FenwickState`) oracle that ingested every token
//! recurrently.

use crate::attention::loglinear::ChunkFenwick;
use crate::prefill::engine::PrefillEngine;
use crate::state::pool::StatePool;
use crate::state::pooled::{PoolExhausted, PooledFenwickState};

/// Export a single-head [`ChunkFenwick`] hierarchy at the `chunks`-chunk
/// boundary into a pool-backed decode state at token position
/// `t = chunks · chunk_size`. The engine must be post-`advance(chunks)`
/// (chunk sentinel merged). Fails without touching the pool if it cannot
/// hold the live states.
pub fn export_chunk_fenwick(
    eng: &ChunkFenwick,
    chunks: usize,
    chunk_size: usize,
    dk: usize,
    dv: usize,
    pool: &mut StatePool,
) -> Result<PooledFenwickState, PoolExhausted> {
    assert!(chunk_size >= 1 && chunk_size.is_power_of_two(), "chunk size must be a power of two");
    assert!(
        !eng.has_level0(),
        "export requires the chunk sentinel merged: call advance(chunks) first"
    );
    let (edk, edv) = eng.state_dims();
    if edk != 0 {
        assert_eq!((edk, edv), (dk, dv), "state shape mismatch");
    }
    let lc = chunk_size.trailing_zeros() as usize;
    let states: Vec<(usize, &[f32])> = eng.active().map(|(m, s)| (lc + m, &s.data[..])).collect();
    assert_eq!(
        states.len(),
        chunks.count_ones() as usize,
        "live chunk levels must cover every bucket of the partition of {chunks} chunks"
    );
    PooledFenwickState::import_levels(pool, dk, dv, chunks << lc, &states)
}

/// Export one head of a finished [`PrefillEngine`] into a pool-backed
/// decode state at token position `engine.tokens()`. Fails without
/// touching the pool if it cannot hold the live states.
pub fn export_prefill_head(
    eng: &PrefillEngine,
    head: usize,
    pool: &mut StatePool,
) -> Result<PooledFenwickState, PoolExhausted> {
    let (dk, dv) = eng.state_dims();
    let states = eng.export_head(head);
    assert_eq!(
        states.len(),
        eng.chunks().count_ones() as usize,
        "live levels must cover every bucket of the partition of {} chunks",
        eng.chunks()
    );
    PooledFenwickState::import_levels(pool, dk, dv, eng.tokens(), &states)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttnInputs;
    use crate::prefill::engine::PrefillEngine;
    use crate::state::{FenwickState, Transition};
    use crate::tensor::{self, Mat};
    use crate::util::Rng;

    /// Single-head Mamba-2 chunk ingestion into a ChunkFenwick (the state
    /// half of `loglinear_mamba2::chunkwise`), advanced to the boundary.
    fn ingest_chunks_mamba2(k: &Mat, v: &Mat, alpha: &[f32], c: usize, chunks: usize) -> ChunkFenwick {
        let (dk, dv) = (k.cols, v.cols);
        let mut eng = ChunkFenwick::new();
        let mut wscale = vec![0.0f32; c];
        for z in 0..chunks {
            let start = z * c;
            eng.advance(z);
            let mut g = vec![0.0f32; c];
            let mut acc = 1.0f64;
            for i in 0..c {
                acc *= alpha[start + i] as f64;
                g[i] = acc as f32;
            }
            let chunk_decay = g[c - 1];
            for j in 0..c {
                wscale[j] = chunk_decay / g[j];
            }
            let mut w = eng.take_buffer(dk, dv);
            tensor::gemm_tn_diag_acc(
                c,
                dk,
                dv,
                &wscale,
                k.rows_data(start, start + c),
                v.rows_data(start, start + c),
                &mut w.data,
            );
            eng.apply_transition(|s| s.scale_inplace(chunk_decay));
            eng.set_level0(w);
        }
        eng.advance(chunks);
        eng
    }

    /// THE acceptance property: a ChunkFenwick hierarchy exported at an
    /// arbitrary chunk boundary, then decoded token-by-token through the
    /// pooled state, matches the FenwickState oracle that ingested every
    /// token recurrently — within the existing chunkwise tolerance.
    #[test]
    fn exported_chunk_fenwick_decodes_like_the_fenwick_oracle() {
        let mut rng = Rng::new(0xB41D);
        let (dk, dv, c) = (8usize, 6usize, 8usize);
        for &chunks in &[1usize, 2, 3, 5, 8, 11] {
            let t0 = chunks * c; // export position
            let t_len = t0 + 9; // decode tail after the boundary
            let x = AttnInputs::random(t_len, dk, dv, &mut rng);
            let eng = ingest_chunks_mamba2(&x.k, &x.v, &x.alpha, c, chunks);

            let mut pool = StatePool::new(dk * dv, 32);
            let mut seq = export_chunk_fenwick(&eng, chunks, c, dk, dv, &mut pool).unwrap();
            assert_eq!(seq.t, t0);
            assert_eq!(seq.live_states(), chunks.count_ones() as usize);

            // oracle: every token through the recurrent state machine
            let mut oracle = FenwickState::new(dk, dv);
            for t in 0..t_len {
                let o_want = oracle.step(
                    x.q.row(t),
                    x.k.row(t),
                    x.v.row(t),
                    1.0,
                    Transition::Decay(x.alpha[t]),
                    x.lambda.row(t),
                );
                if t >= t0 {
                    let o_got = seq
                        .step(
                            &mut pool,
                            x.q.row(t),
                            x.k.row(t),
                            x.v.row(t),
                            1.0,
                            Transition::Decay(x.alpha[t]),
                            x.lambda.row(t),
                        )
                        .unwrap();
                    for j in 0..dv {
                        assert!(
                            (o_got[j] - o_want[j]).abs() < 2e-3 + 2e-3 * o_want[j].abs(),
                            "chunks={chunks} t={t} j={j}: {} vs {}",
                            o_got[j],
                            o_want[j]
                        );
                    }
                    assert_eq!(seq.live_states(), oracle.live_states(), "chunks={chunks} t={t}");
                }
            }
            seq.release(&mut pool);
            assert_eq!(pool.in_use(), 0);
        }
    }

    /// Multi-head prefill-vs-oracle equivalence, both variants: full
    /// chunks through the head-batched engine, the sub-chunk tail
    /// token-by-token through the pooled state, then a decode tail —
    /// every post-prefill output matches the per-head FenwickState oracle.
    #[test]
    fn prefilled_heads_decode_like_per_head_oracles_both_variants() {
        let mut rng = Rng::new(0xB42D);
        let (heads, dk, dv, c) = (2usize, 8usize, 8usize, 8usize);
        let prompt = 37usize; // 4 full chunks + 5-token tail
        let decode = 6usize;
        let t_len = prompt + decode;
        let shared = AttnInputs::random(t_len, dk, dv, &mut rng); // gates + λ
        // L2-normalized keys, as everywhere else: keeps the GDN
        // Householder transitions contractive
        let ks: Vec<Mat> = (0..heads)
            .map(|_| {
                let mut k = Mat::randn(t_len, dk, 1.0, &mut rng);
                for i in 0..t_len {
                    let n = crate::tensor::ops::l2_norm(k.row(i)).max(1e-6);
                    for x in k.row_mut(i) {
                        *x /= n;
                    }
                }
                k
            })
            .collect();
        let vs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dv, 1.0, &mut rng)).collect();
        let qs: Vec<Mat> = (0..heads).map(|_| Mat::randn(t_len, dk, 1.0, &mut rng)).collect();
        let nchunks = prompt / c;

        let mut ws = crate::prefill::Workspace::new();
        for gdn in [false, true] {
            // head-batched chunkwise ingestion of the full chunks
            let mut eng = PrefillEngine::new(heads, dk, dv, c);
            for z in 0..nchunks {
                let (s, e) = (z * c, (z + 1) * c);
                let mut kc = Vec::new();
                let mut vc = Vec::new();
                for h in 0..heads {
                    kc.extend_from_slice(ks[h].rows_data(s, e));
                    vc.extend_from_slice(vs[h].rows_data(s, e));
                }
                if gdn {
                    eng.ingest_chunk_gdn(&mut ws, &kc, &vc, &shared.alpha[s..e], &shared.beta[s..e], None);
                } else {
                    eng.ingest_chunk_mamba2(&mut ws, &kc, &vc, &shared.alpha[s..e], None);
                }
            }
            eng.finish();
            assert_eq!(eng.tokens(), nchunks * c);

            let mut pool = StatePool::new(dk * dv, heads * 16);
            for h in 0..heads {
                let mut seq = export_prefill_head(&eng, h, &mut pool).unwrap();
                let mut oracle = FenwickState::new(dk, dv);
                for t in 0..t_len {
                    let (ws, tr_o, tr_p) = if gdn {
                        (
                            shared.beta[t],
                            Transition::GatedHouseholder {
                                alpha: shared.alpha[t],
                                beta: shared.beta[t],
                                k: ks[h].row(t),
                            },
                            Transition::GatedHouseholder {
                                alpha: shared.alpha[t],
                                beta: shared.beta[t],
                                k: ks[h].row(t),
                            },
                        )
                    } else {
                        (1.0, Transition::Decay(shared.alpha[t]), Transition::Decay(shared.alpha[t]))
                    };
                    let o_want = oracle.step(
                        qs[h].row(t),
                        ks[h].row(t),
                        vs[h].row(t),
                        ws,
                        tr_o,
                        shared.lambda.row(t),
                    );
                    if t >= nchunks * c {
                        // tail + decode: token steps on the exported state
                        let o_got = seq
                            .step(
                                &mut pool,
                                qs[h].row(t),
                                ks[h].row(t),
                                vs[h].row(t),
                                ws,
                                tr_p,
                                shared.lambda.row(t),
                            )
                            .unwrap();
                        for j in 0..dv {
                            assert!(
                                (o_got[j] - o_want[j]).abs() < 2e-3 + 2e-3 * o_want[j].abs(),
                                "gdn={gdn} head={h} t={t} j={j}: {} vs {}",
                                o_got[j],
                                o_want[j]
                            );
                        }
                    }
                }
                seq.release(&mut pool);
            }
            assert_eq!(pool.in_use(), 0);
        }
    }

    /// The bridge is precision-agnostic: `import_levels` narrows the
    /// exported chunk states when the destination pool stores bf16, and
    /// the resulting decode stays within the documented tolerance of the
    /// f32-pool export (docs/PRECISION.md) at half the resident bytes.
    #[test]
    fn export_into_bf16_pool_decodes_within_tolerance() {
        use crate::state::pool::Precision;
        let mut rng = Rng::new(0xB44D);
        let (dk, dv, c, chunks) = (8usize, 6usize, 8usize, 5usize);
        let t0 = chunks * c;
        let t_len = t0 + 7;
        let x = AttnInputs::random(t_len, dk, dv, &mut rng);
        let eng = ingest_chunks_mamba2(&x.k, &x.v, &x.alpha, c, chunks);

        let mut pool_f = StatePool::new(dk * dv, 32);
        let mut pool_h = StatePool::with_precision(dk * dv, 32, Precision::Bf16);
        assert_eq!(pool_f.bytes_per_block(), 2 * pool_h.bytes_per_block());
        let mut seq_f = export_chunk_fenwick(&eng, chunks, c, dk, dv, &mut pool_f).unwrap();
        let mut seq_h = export_chunk_fenwick(&eng, chunks, c, dk, dv, &mut pool_h).unwrap();
        assert_eq!(seq_h.t, t0);
        assert_eq!(seq_h.live_states(), chunks.count_ones() as usize);

        for t in t0..t_len {
            let step = |seq: &mut PooledFenwickState, pool: &mut StatePool| {
                seq.step(
                    pool,
                    x.q.row(t),
                    x.k.row(t),
                    x.v.row(t),
                    1.0,
                    Transition::Decay(x.alpha[t]),
                    x.lambda.row(t),
                )
                .unwrap()
            };
            let o_f = step(&mut seq_f, &mut pool_f);
            let o_h = step(&mut seq_h, &mut pool_h);
            for j in 0..dv {
                let rel = (o_f[j] - o_h[j]).abs() / (1.0 + o_f[j].abs());
                assert!(rel <= 0.05, "t={t} j={j}: bf16 export drifted ({} vs {})", o_h[j], o_f[j]);
            }
        }
        seq_f.release(&mut pool_f);
        seq_h.release(&mut pool_h);
        assert_eq!((pool_f.in_use(), pool_h.in_use()), (0, 0));
    }

    #[test]
    fn export_fails_cleanly_on_pool_exhaustion() {
        let mut rng = Rng::new(0xB43D);
        let (dk, dv, c, chunks) = (4usize, 4usize, 4usize, 7usize); // 3 live levels
        let t_len = chunks * c;
        let x = AttnInputs::random(t_len, dk, dv, &mut rng);
        let eng = ingest_chunks_mamba2(&x.k, &x.v, &x.alpha, c, chunks);
        let mut pool = StatePool::new(dk * dv, 2); // too small for 3 states
        assert_eq!(
            export_chunk_fenwick(&eng, chunks, c, dk, dv, &mut pool).unwrap_err(),
            PoolExhausted
        );
        assert_eq!(pool.in_use(), 0, "failed export must not leak blocks");
        pool.grow(1);
        let mut seq = export_chunk_fenwick(&eng, chunks, c, dk, dv, &mut pool).unwrap();
        assert_eq!(pool.in_use(), 3);
        seq.release(&mut pool);
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    #[should_panic(expected = "not live at position")]
    fn import_rejects_misaligned_levels() {
        let mut pool = StatePool::new(4, 4);
        let data = vec![0.0f32; 4];
        // level 1 requires bit 0 of t set; t = 4 has it clear
        let _ = PooledFenwickState::import_levels(&mut pool, 2, 2, 4, &[(1, &data[..])]);
    }
}
