//! The four repo-specific invariant lints, plus the waiver checker.
//!
//! Catalogue (see docs/ANALYSIS.md for the full contracts):
//!
//! * `determinism` — no `HashMap`/`HashSet` in the serving-path modules
//!   (`src/coordinator/`, `src/state/`, `src/prefill/`, `src/tensor/`).
//!   Iteration order there can reach logits or dispatch order, and the
//!   whole stack's safety lock is the bit-exact differential trace
//!   harness; use `BTreeMap`/`BTreeSet` or sorted vecs.
//! * `refcount` — a function that calls `StatePool::retain` (any
//!   `.retain(` whose argument is not a `|…|` predicate, to exclude
//!   `Vec::retain`) must also call `.release(` somewhere in its body, or
//!   carry an ownership-transfer waiver documenting where the reference
//!   goes.
//! * `unsafe` — every `unsafe` token carries a `// SAFETY:` comment on
//!   the same line or in the contiguous comment block directly above.
//! * `hot_alloc` — functions marked `// xtask: deny_alloc` (decode /
//!   advance hot paths) must not contain allocation tokens
//!   (`Vec::new`, `vec!`, `.clone(`, `.to_vec(`, `Box::new`, …).
//!
//! Waiver syntax, uniform across lints: a comment on the offending line
//! or within the two lines above reading
//! `xtask: allow(<lint>): <non-empty reason>`. A waiver without the
//! reason (or naming an unknown lint) is itself reported, as lint
//! `waiver` — an undocumented exemption is exactly the convention-rot
//! this pass exists to prevent.

use crate::scan::{next_nonspace, token_positions, SourceFile};

/// Lint names accepted by `xtask: allow(<lint>)`.
pub const LINT_NAMES: &[&str] = &["determinism", "refcount", "unsafe", "hot_alloc"];

/// Serving-path directories covered by the determinism lint.
const DET_DIRS: &[&str] =
    &["src/coordinator/", "src/state/", "src/prefill/", "src/tensor/", "src/obs/"];

/// Allocation tokens denied inside `// xtask: deny_alloc` functions.
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "Vec::with_capacity",
    "vec!",
    ".to_vec(",
    ".clone(",
    ".to_owned(",
    // no trailing `(` — must also catch turbofish `.collect::<T>()`
    ".collect",
    "Box::new",
    "String::new",
    "format!",
];

pub struct Finding {
    pub lint: &'static str,
    pub rel: String,
    /// 1-based, ready for `path:line` display.
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.rel, self.line, self.lint, self.msg)
    }
}

/// Run every lint over one file; findings sorted by (line, lint).
pub fn lint_file(f: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    determinism(f, &mut out);
    refcount(f, &mut out);
    unsafe_hygiene(f, &mut out);
    hot_alloc(f, &mut out);
    waiver_syntax(f, &mut out);
    out.sort_by(|a, b| (a.line, a.lint).cmp(&(b.line, b.lint)));
    out
}

struct Waiver {
    lint: String,
    has_reason: bool,
}

/// Parse `xtask: allow(<lint>): <reason>` out of one comment line.
fn parse_waiver(comment: &str) -> Option<Waiver> {
    let idx = comment.find("xtask: allow(")?;
    let rest = &comment[idx + "xtask: allow(".len()..];
    let close = rest.find(')')?;
    let lint = rest[..close].trim().to_string();
    let after = rest[close + 1..].trim_start();
    let has_reason = after.strip_prefix(':').is_some_and(|r| !r.trim().is_empty());
    Some(Waiver { lint, has_reason })
}

/// Is a finding of `lint` at (0-based) `line` covered by a *valid*
/// waiver on that line or within the two lines above?
fn waived(f: &SourceFile, line: usize, lint: &str) -> bool {
    (line.saturating_sub(2)..=line).any(|l| {
        parse_waiver(&f.comments[l]).is_some_and(|w| w.lint == lint && w.has_reason)
    })
}

fn determinism(f: &SourceFile, out: &mut Vec<Finding>) {
    if !DET_DIRS.iter().any(|d| f.rel.starts_with(d)) {
        return;
    }
    for (ln, code) in f.code.iter().enumerate() {
        for tok in ["HashMap", "HashSet"] {
            if token_positions(code, tok).is_empty() || waived(f, ln, "determinism") {
                continue;
            }
            out.push(Finding {
                lint: "determinism",
                rel: f.rel.clone(),
                line: ln + 1,
                msg: format!(
                    "{tok} in a serving-path module: iteration order is nondeterministic and \
                     must never reach numeric computation or dispatch order — use \
                     BTreeMap/BTreeSet or a sorted Vec"
                ),
            });
        }
    }
}

fn refcount(f: &SourceFile, out: &mut Vec<Finding>) {
    for (ln, code) in f.code.iter().enumerate() {
        for col in token_positions(code, ".retain(") {
            // `Vec::retain(|x| …)` takes a predicate; pool retains take
            // a block id. Distinguish on the first argument character.
            if next_nonspace(&f.code, ln, col + ".retain(".len()) == Some('|') {
                continue;
            }
            if f.in_test_span(ln) {
                continue;
            }
            let Some(func) = f.enclosing_fn(ln) else { continue };
            let (a, b) = func.body.expect("enclosing_fn only returns bodied fns");
            let released =
                f.code[a..=b].iter().any(|l| !token_positions(l, ".release(").is_empty());
            if released || waived(f, ln, "refcount") {
                continue;
            }
            out.push(Finding {
                lint: "refcount",
                rel: f.rel.clone(),
                line: ln + 1,
                msg: format!(
                    "`{}` takes a pool reference via retain() but never calls release(); \
                     pair it or document the ownership transfer with \
                     `xtask: allow(refcount): <where the ref goes>`",
                    func.name
                ),
            });
        }
    }
}

fn unsafe_hygiene(f: &SourceFile, out: &mut Vec<Finding>) {
    for (ln, code) in f.code.iter().enumerate() {
        for _ in token_positions(code, "unsafe") {
            if has_safety_comment(f, ln) || waived(f, ln, "unsafe") {
                continue;
            }
            out.push(Finding {
                lint: "unsafe",
                rel: f.rel.clone(),
                line: ln + 1,
                msg: "unsafe without a `// SAFETY:` contract on the same line or in the \
                      comment block directly above"
                    .to_string(),
            });
        }
    }
}

/// `// SAFETY:` on the finding line, or anywhere in the contiguous run
/// of comment-only / attribute lines directly above it.
fn has_safety_comment(f: &SourceFile, line: usize) -> bool {
    if f.comments[line].contains("SAFETY:") {
        return true;
    }
    let mut l = line;
    while l > 0 {
        l -= 1;
        let code_blank = f.code[l].trim().is_empty();
        let attr = f.code[l].trim_start().starts_with("#[");
        if !(code_blank && !f.comments[l].is_empty()) && !attr {
            return false;
        }
        if f.comments[l].contains("SAFETY:") {
            return true;
        }
    }
    false
}

fn hot_alloc(f: &SourceFile, out: &mut Vec<Finding>) {
    for func in &f.fns {
        let Some((a, b)) = func.body else { continue };
        if !deny_alloc_marked(f, func.line) {
            continue;
        }
        for ln in a..=b {
            for tok in ALLOC_TOKENS {
                if token_positions(&f.code[ln], tok).is_empty() || waived(f, ln, "hot_alloc") {
                    continue;
                }
                out.push(Finding {
                    lint: "hot_alloc",
                    rel: f.rel.clone(),
                    line: ln + 1,
                    msg: format!(
                        "`{tok}` inside `{}`, which is marked `xtask: deny_alloc` (decode/advance \
                         hot path): allocations here turn the steady-state token loop O(alloc)",
                        func.name
                    ),
                });
            }
        }
    }
}

/// Does the contiguous comment/attribute block above the `fn` line (or a
/// trailing comment on it) carry the `xtask: deny_alloc` marker?
fn deny_alloc_marked(f: &SourceFile, fn_line: usize) -> bool {
    if f.comments[fn_line].contains("xtask: deny_alloc") {
        return true;
    }
    let mut l = fn_line;
    let mut steps = 0;
    while l > 0 && steps < 12 {
        l -= 1;
        steps += 1;
        let code_blank = f.code[l].trim().is_empty();
        let comment_only = code_blank && !f.comments[l].is_empty();
        let attr = f.code[l].trim_start().starts_with("#[");
        if !comment_only && !attr {
            return false;
        }
        if f.comments[l].contains("xtask: deny_alloc") {
            return true;
        }
    }
    false
}

/// Malformed waivers are findings too: an exemption without a reason (or
/// for an unknown lint) silently rots into folklore.
fn waiver_syntax(f: &SourceFile, out: &mut Vec<Finding>) {
    for (ln, comment) in f.comments.iter().enumerate() {
        let Some(w) = parse_waiver(comment) else { continue };
        if !LINT_NAMES.contains(&w.lint.as_str()) {
            out.push(Finding {
                lint: "waiver",
                rel: f.rel.clone(),
                line: ln + 1,
                msg: format!(
                    "waiver names unknown lint `{}` (known: {})",
                    w.lint,
                    LINT_NAMES.join(", ")
                ),
            });
        } else if !w.has_reason {
            out.push(Finding {
                lint: "waiver",
                rel: f.rel.clone(),
                line: ln + 1,
                msg: format!(
                    "waiver for `{}` has no justification — write \
                     `xtask: allow({}): <why this is sound>` (reasonless waivers do not \
                     suppress the finding)",
                    w.lint, w.lint
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::SourceFile;

    fn lints_on(rel: &str, src: &str) -> Vec<Finding> {
        lint_file(&SourceFile::parse(rel, src))
    }

    #[test]
    fn determinism_is_dir_scoped() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(lints_on("src/state/x.rs", src).len(), 1);
        assert_eq!(lints_on("src/data/x.rs", src).len(), 0);
    }

    #[test]
    fn determinism_waiver_with_reason_suppresses() {
        let ok = "use std::collections::HashMap; // xtask: allow(determinism): counts only\n";
        assert!(lints_on("src/state/x.rs", ok).is_empty());
        let bad = "use std::collections::HashMap; // xtask: allow(determinism)\n";
        let got = lints_on("src/state/x.rs", bad);
        // Reasonless waiver: the original finding stands AND the waiver
        // itself is flagged.
        assert_eq!(got.len(), 2);
        assert!(got.iter().any(|f| f.lint == "determinism"));
        assert!(got.iter().any(|f| f.lint == "waiver"));
    }

    #[test]
    fn refcount_requires_release_or_waiver() {
        let bad = "fn leak(p: &mut Pool, id: BlockId) {\n    p.retain(id);\n}\n";
        let got = lints_on("src/state/x.rs", bad);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].lint, "refcount");
        assert_eq!(got[0].line, 2);

        let paired = "fn ok(p: &mut Pool, a: BlockId, b: BlockId) {\n    p.retain(a);\n    p.release(b);\n}\n";
        assert!(lints_on("src/state/x.rs", paired).is_empty());

        let waived = "fn adopt(p: &mut Pool, id: BlockId) {\n    // xtask: allow(refcount): ref transferred to cache entry\n    p.retain(id);\n}\n";
        assert!(lints_on("src/state/x.rs", waived).is_empty());
    }

    #[test]
    fn vec_retain_predicates_are_not_pool_retains() {
        let src = "fn prune(v: &mut Vec<u32>) {\n    v.retain(|x| *x > 0);\n}\n";
        assert!(lints_on("src/state/x.rs", src).is_empty());
        // …including when the closure starts on the next line.
        let src2 = "fn prune(v: &mut Vec<u32>) {\n    v.retain(\n        |x| *x > 0,\n    );\n}\n";
        assert!(lints_on("src/state/x.rs", src2).is_empty());
    }

    #[test]
    fn retain_inside_test_modules_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        pool.retain(id);\n    }\n}\n";
        assert!(lints_on("src/state/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f() {\n    unsafe { danger() }\n}\n";
        let got = lints_on("src/util/x.rs", bad);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].lint, "unsafe");

        let same_line = "fn f() {\n    unsafe { danger() } // SAFETY: checked above\n}\n";
        assert!(lints_on("src/util/x.rs", same_line).is_empty());

        let block_above = "fn f() {\n    // SAFETY: `danger` only reads, and the buffer\n    // outlives this call (see the scope barrier).\n    unsafe { danger() }\n}\n";
        assert!(lints_on("src/util/x.rs", block_above).is_empty());

        let gap = "fn f() {\n    // SAFETY: stale, detached contract\n    let x = 1;\n    unsafe { danger() }\n}\n";
        assert_eq!(lints_on("src/util/x.rs", gap).len(), 1);
    }

    #[test]
    fn hot_alloc_fires_only_in_marked_fns() {
        let unmarked = "fn cold() -> Vec<f32> {\n    Vec::new()\n}\n";
        assert!(lints_on("src/tensor/x.rs", unmarked).is_empty());

        let marked = "// xtask: deny_alloc\nfn hot(xs: &[f32]) -> Vec<f32> {\n    xs.to_vec()\n}\n";
        let got = lints_on("src/tensor/x.rs", marked);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].lint, "hot_alloc");
        assert_eq!(got[0].line, 3);

        let clean = "// xtask: deny_alloc\n#[inline]\nfn hot(xs: &mut [f32]) {\n    for x in xs.iter_mut() { *x *= 2.0; }\n}\n";
        assert!(lints_on("src/tensor/x.rs", clean).is_empty());

        let waived = "// xtask: deny_alloc\nfn hot(xs: &[f32]) -> Vec<f32> {\n    // xtask: allow(hot_alloc): cold-start snapshot, not per-token\n    xs.to_vec()\n}\n";
        assert!(lints_on("src/tensor/x.rs", waived).is_empty());
    }

    #[test]
    fn unknown_lint_waivers_are_flagged() {
        let src = "// xtask: allow(speed): because\nfn f() {}\n";
        let got = lints_on("src/util/x.rs", src);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].lint, "waiver");
    }

    #[test]
    fn tokens_in_comments_and_strings_do_not_fire() {
        let src = "// HashMap would be wrong here\nfn f() -> &'static str {\n    \"HashMap\"\n}\n";
        assert!(lints_on("src/state/x.rs", src).is_empty());
    }
}
