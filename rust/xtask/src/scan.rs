//! Comment/string-aware source model for the lint passes.
//!
//! The lints in [`crate::lints`] are token scans, so the first job is to
//! make token scanning *sound*: a `HashMap` in a doc comment or a string
//! literal must never fire the determinism lint, and a waiver written in
//! code (inside a string) must never silence one. [`scrub`] runs a small
//! lexer state machine over the file and splits every line into a *code*
//! projection (comments and string contents blanked to spaces, columns
//! preserved) and a *comment* projection (the comment text on that line).
//! Lints search the code projection; waiver/`SAFETY:` checks search the
//! comment projection. On top of that, [`find_fns`] brace-matches `fn`
//! bodies (for the function-scoped lints) and [`find_test_spans`] locates
//! `#[cfg(test)] mod` regions so test-only code can be exempted where a
//! lint's contract is about serving paths.
//!
//! The lexer understands line/nested-block comments, string literals with
//! escapes (incl. multi-line), `r"…"`/`r#"…"#` raw strings, char literals
//! vs lifetime ticks, and byte literals. It is deliberately *not* a full
//! Rust lexer — it only needs to be exact about where comments and
//! strings begin and end, which the above covers for this codebase and
//! the fixture corpus (asserted by the unit tests below).

use std::fs;
use std::path::Path;

/// One scanned `.rs` file.
pub struct SourceFile {
    /// Path relative to the scan root, `/`-separated (e.g.
    /// `src/state/pool.rs`). Dir-scoped lints match on this.
    pub rel: String,
    /// Code projection, one entry per source line: comments and string
    /// *contents* replaced by spaces (quotes kept), columns preserved.
    pub code: Vec<String>,
    /// Comment projection: the comment text found on each line
    /// (including the `//` / `/*` markers), empty if none.
    pub comments: Vec<String>,
    /// Every `fn` item found, in source order (nested fns included).
    pub fns: Vec<FnSpan>,
    /// Inclusive 0-based line ranges of `#[cfg(test)] mod … { … }`.
    pub test_spans: Vec<(usize, usize)>,
}

/// A `fn` item: where its `fn` keyword sits and the inclusive line range
/// of its `{ … }` body (`None` for bodyless trait-method declarations).
pub struct FnSpan {
    pub name: String,
    pub line: usize,
    pub body: Option<(usize, usize)>,
}

impl SourceFile {
    pub fn parse(rel: &str, src: &str) -> SourceFile {
        let (code, comments) = scrub(src);
        let fns = find_fns(&code);
        let test_spans = find_test_spans(&code);
        SourceFile { rel: rel.to_string(), code, comments, fns, test_spans }
    }

    pub fn load(root: &Path, rel: &str) -> std::io::Result<SourceFile> {
        let src = fs::read_to_string(root.join(rel))?;
        Ok(SourceFile::parse(rel, &src))
    }

    /// Is this (0-based) line inside a `#[cfg(test)] mod` block?
    pub fn in_test_span(&self, line: usize) -> bool {
        self.test_spans.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// The innermost `fn` whose body contains `line`.
    pub fn enclosing_fn(&self, line: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.body.is_some_and(|(a, b)| a <= line && line <= b))
            .min_by_key(|f| {
                let (a, b) = f.body.unwrap();
                b - a
            })
    }
}

pub fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte positions of `tok` in `line` occurring at identifier boundaries:
/// if `tok` starts (ends) with an identifier char, the preceding
/// (following) byte must not be one. `vec!` therefore matches in
/// `vec![0.0; n]` but `Hash` does not match inside `HashMap`.
pub fn token_positions(line: &str, tok: &str) -> Vec<usize> {
    let bytes = line.as_bytes();
    let first_ident = tok.chars().next().is_some_and(is_ident);
    let last_ident = tok.chars().next_back().is_some_and(is_ident);
    let mut out = Vec::new();
    for (pos, _) in line.match_indices(tok) {
        if first_ident && pos > 0 && is_ident(bytes[pos - 1] as char) {
            continue;
        }
        let end = pos + tok.len();
        if last_ident && end < bytes.len() && is_ident(bytes[end] as char) {
            continue;
        }
        out.push(pos);
    }
    out
}

/// First non-whitespace char at or after byte `col` of line `line`,
/// scanning across subsequent lines.
pub fn next_nonspace(code: &[String], line: usize, col: usize) -> Option<char> {
    let mut ln = line;
    let mut start = col;
    while ln < code.len() {
        if let Some(c) = code[ln][start.min(code[ln].len())..].chars().find(|c| !c.is_whitespace())
        {
            return Some(c);
        }
        ln += 1;
        start = 0;
    }
    None
}

/// The lexer: split `src` into per-line (code, comment) projections.
fn scrub(src: &str) -> (Vec<String>, Vec<String>) {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Normal,
        Line,
        Block(u32),
        Str,
        RawStr(u32),
        Chr,
    }
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut code_lines: Vec<String> = Vec::new();
    let mut com_lines: Vec<String> = Vec::new();
    let mut code = String::new();
    let mut com = String::new();
    let mut st = St::Normal;
    // Last non-whitespace code char, for `r"…"`-vs-identifier decisions.
    let mut prev_code = ' ';
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            code_lines.push(std::mem::take(&mut code));
            com_lines.push(std::mem::take(&mut com));
            if st == St::Line {
                st = St::Normal;
            }
            i += 1;
            continue;
        }
        match st {
            St::Normal => {
                let next = cs.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::Line;
                    code.push_str("  ");
                    com.push_str("//");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(1);
                    code.push_str("  ");
                    com.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    code.push('"');
                    com.push(' ');
                    prev_code = '"';
                    i += 1;
                } else if c == 'r' && !is_ident(prev_code) && raw_str_hashes(&cs, i + 1).is_some() {
                    let h = raw_str_hashes(&cs, i + 1).unwrap();
                    st = St::RawStr(h);
                    code.push('r');
                    for _ in 0..h {
                        code.push('#');
                    }
                    code.push('"');
                    for _ in 0..h as usize + 2 {
                        com.push(' ');
                    }
                    prev_code = '"';
                    i += h as usize + 2;
                } else if c == '\'' {
                    // Char literal or lifetime tick. `'\…'` and `'x'`
                    // are literals; anything else (`'env`, `'_`) is a
                    // lifetime and only the tick is consumed.
                    if next == Some('\\') {
                        st = St::Chr;
                        code.push('\'');
                        com.push(' ');
                        i += 1;
                    } else if cs.get(i + 2) == Some(&'\'') && next.is_some_and(|ch| ch != '\'') {
                        code.push_str("' '");
                        com.push_str("   ");
                        prev_code = '\'';
                        i += 3;
                    } else {
                        code.push('\'');
                        com.push(' ');
                        prev_code = '\'';
                        i += 1;
                    }
                } else {
                    code.push(c);
                    com.push(' ');
                    if !c.is_whitespace() {
                        prev_code = c;
                    }
                    i += 1;
                }
            }
            St::Line => {
                com.push(c);
                code.push(' ');
                i += 1;
            }
            St::Block(d) => {
                if c == '/' && cs.get(i + 1) == Some(&'*') {
                    st = St::Block(d + 1);
                    com.push_str("/*");
                    code.push_str("  ");
                    i += 2;
                } else if c == '*' && cs.get(i + 1) == Some(&'/') {
                    st = if d == 1 { St::Normal } else { St::Block(d - 1) };
                    com.push_str("*/");
                    code.push_str("  ");
                    i += 2;
                } else {
                    com.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' && i + 1 < n && cs[i + 1] != '\n' {
                    code.push_str("  ");
                    com.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    st = St::Normal;
                    code.push('"');
                    com.push(' ');
                    prev_code = '"';
                    i += 1;
                } else {
                    code.push(' ');
                    com.push(' ');
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' {
                    let mut k = 0u32;
                    while k < h && cs.get(i + 1 + k as usize) == Some(&'#') {
                        k += 1;
                    }
                    if k == h {
                        st = St::Normal;
                        code.push('"');
                        for _ in 0..h {
                            code.push('#');
                        }
                        for _ in 0..h as usize + 1 {
                            com.push(' ');
                        }
                        prev_code = '"';
                        i += h as usize + 1;
                    } else {
                        code.push(' ');
                        com.push(' ');
                        i += 1;
                    }
                } else {
                    code.push(' ');
                    com.push(' ');
                    i += 1;
                }
            }
            St::Chr => {
                if c == '\\' && i + 1 < n {
                    code.push_str("  ");
                    com.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    st = St::Normal;
                    code.push('\'');
                    com.push(' ');
                    prev_code = '\'';
                    i += 1;
                } else {
                    code.push(' ');
                    com.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !com.is_empty() {
        code_lines.push(code);
        com_lines.push(com);
    }
    (code_lines, com_lines)
}

/// If `cs[from..]` is `#*"` (a raw-string opener after an `r`), the
/// number of `#`s; else `None`.
fn raw_str_hashes(cs: &[char], from: usize) -> Option<u32> {
    let mut j = from;
    let mut h = 0u32;
    while cs.get(j) == Some(&'#') {
        h += 1;
        j += 1;
    }
    if cs.get(j) == Some(&'"') {
        Some(h)
    } else {
        None
    }
}

/// Find every `fn` item in the code projection and brace-match its body.
fn find_fns(code: &[String]) -> Vec<FnSpan> {
    // Flatten to a (char, line) stream so signatures and bodies can span
    // lines without special cases.
    let mut chars: Vec<(char, usize)> = Vec::new();
    for (ln, l) in code.iter().enumerate() {
        for ch in l.chars() {
            chars.push((ch, ln));
        }
        chars.push(('\n', ln));
    }
    let n = chars.len();
    let mut fns = Vec::new();
    let mut i = 0usize;
    while i < n {
        let (c, ln) = chars[i];
        let kw = c == 'f'
            && i + 1 < n
            && chars[i + 1].0 == 'n'
            && (i == 0 || !is_ident(chars[i - 1].0))
            && (i + 2 >= n || !is_ident(chars[i + 2].0));
        if !kw {
            i += 1;
            continue;
        }
        let mut j = i + 2;
        while j < n && chars[j].0.is_whitespace() {
            j += 1;
        }
        let mut name = String::new();
        while j < n && is_ident(chars[j].0) {
            name.push(chars[j].0);
            j += 1;
        }
        if name.is_empty() {
            // `fn(...)` pointer type, not an item.
            i += 2;
            continue;
        }
        // Scan the signature for the body `{` at bracket depth 0; a `;`
        // first means a bodyless declaration.
        let mut depth = 0i32;
        let mut body = None;
        while j < n {
            match chars[j].0 {
                '(' | '[' => depth += 1,
                ')' | ']' => depth -= 1,
                ';' if depth == 0 => break,
                '{' if depth == 0 => {
                    let start_ln = chars[j].1;
                    let mut braces = 1i32;
                    let mut k = j + 1;
                    while k < n && braces > 0 {
                        match chars[k].0 {
                            '{' => braces += 1,
                            '}' => braces -= 1,
                            _ => {}
                        }
                        k += 1;
                    }
                    let end_ln = chars[k.saturating_sub(1)].1;
                    body = Some((start_ln, end_ln));
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        fns.push(FnSpan { name, line: ln, body });
        // Continue from the signature end; nested fns inside the body
        // are still discovered because the scan walks *into* it.
        i = j;
    }
    fns
}

/// Inclusive line spans of `#[cfg(test)] mod … { … }` blocks.
fn find_test_spans(code: &[String]) -> Vec<(usize, usize)> {
    let mut spans: Vec<(usize, usize)> = Vec::new();
    for ln in 0..code.len() {
        if code[ln].contains("#[cfg(test)]") || code[ln].contains("#[cfg(all(test") {
            // The `mod` keyword is on this line or shortly after
            // (other attributes may intervene).
            for ml in ln..code.len().min(ln + 4) {
                if spans.iter().any(|&(a, b)| a <= ml && ml <= b) {
                    break;
                }
                if !token_positions(&code[ml], "mod").is_empty() {
                    if let Some(end) = brace_match_from(code, ml) {
                        spans.push((ml, end));
                    }
                    break;
                }
            }
        }
    }
    spans
}

/// Line of the `}` matching the first `{` at or after line `from`.
fn brace_match_from(code: &[String], from: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut opened = false;
    for (ln, l) in code.iter().enumerate().skip(from) {
        for c in l.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth -= 1;
                    if opened && depth == 0 {
                        return Some(ln);
                    }
                }
                _ => {}
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_scrubbed_from_code() {
        let sf = SourceFile::parse(
            "src/x.rs",
            "let a = \"HashMap in a string\"; // HashMap in a comment\nlet b = 1;\n",
        );
        assert!(!sf.code[0].contains("HashMap"));
        assert!(sf.comments[0].contains("HashMap in a comment"));
        assert_eq!(sf.code[1].trim(), "let b = 1;");
    }

    #[test]
    fn lifetimes_do_not_open_char_literals() {
        let src = "fn f<'env>(x: &'env str) -> char { 'x' }\nlet y = HashMap::new();\n";
        let sf = SourceFile::parse("src/x.rs", src);
        // The char literal payload is blanked but the second line is
        // still live code — i.e. the tick did not swallow the rest of
        // the file.
        assert!(sf.code[1].contains("HashMap"));
        assert!(!sf.code[0].contains("'x'"));
    }

    #[test]
    fn escaped_quotes_and_raw_strings() {
        let src = "let a = \"q\\\"HashMap\\\"\"; let b = r#\"HashMap\"#; let c = 'c';\nHashSet\n";
        let sf = SourceFile::parse("src/x.rs", src);
        assert!(!sf.code[0].contains("HashMap"));
        assert!(sf.code[1].contains("HashSet"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ code_here\n";
        let sf = SourceFile::parse("src/x.rs", src);
        assert!(sf.code[0].contains("code_here"));
        assert!(!sf.code[0].contains("still"));
        assert!(sf.comments[0].contains("inner"));
    }

    #[test]
    fn fn_spans_cover_bodies_and_skip_declarations() {
        let src = "trait T {\n    fn decl(&self) -> usize;\n}\nfn outer() {\n    let c = |x: usize| x + 1;\n    fn inner() { body(); }\n    c(2);\n}\n";
        let sf = SourceFile::parse("src/x.rs", src);
        let names: Vec<&str> = sf.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["decl", "outer", "inner"]);
        assert!(sf.fns[0].body.is_none());
        assert_eq!(sf.fns[1].body, Some((3, 7)));
        assert_eq!(sf.fns[2].body, Some((5, 5)));
        // Innermost attribution: line 5 belongs to `inner`.
        assert_eq!(sf.enclosing_fn(5).unwrap().name, "inner");
        assert_eq!(sf.enclosing_fn(6).unwrap().name, "outer");
    }

    #[test]
    fn test_spans_are_detected() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    use super::*;\n    #[test]\n    fn t() { helper(); }\n}\nfn also_live() {}\n";
        let sf = SourceFile::parse("src/x.rs", src);
        assert_eq!(sf.test_spans, vec![(2, 6)]);
        assert!(sf.in_test_span(5));
        assert!(!sf.in_test_span(0));
        assert!(!sf.in_test_span(7));
    }

    #[test]
    fn token_positions_respect_ident_boundaries() {
        assert!(token_positions("let m: HashMap<u64, f32>;", "HashMap").len() == 1);
        assert!(token_positions("let m = NotAHashMapType;", "HashMap").is_empty());
        assert_eq!(token_positions("vec![0.0; n]", "vec!").len(), 1);
        assert_eq!(token_positions("s.retain(x); q.retain(y)", ".retain(").len(), 2);
    }

    #[test]
    fn next_nonspace_crosses_lines() {
        let code = vec!["a.retain(".to_string(), "    |x| x".to_string()];
        assert_eq!(next_nonspace(&code, 0, 9), Some('|'));
    }
}
