//! `cargo run -p xtask -- lint` — repo-specific invariant lints.
//!
//! Commands:
//!
//! * `lint` — scan `rust/src/**/*.rs` with the four lints in
//!   [`lints`]; print findings `path:line: [lint] message`, exit 1 if
//!   any survive waivers. The walk order and output order are sorted, so
//!   two runs over the same tree are byte-identical (the lint pass holds
//!   itself to the determinism standard it enforces).
//! * `lint --self-test` — run the known-bad fixture corpus under
//!   `xtask/fixtures/`: every fixture must trip exactly the lints it
//!   documents, the waivered fixture must pass clean, and all four lint
//!   categories must be exercised. This is the proof that the lints can
//!   actually fire — a linter that never fires is indistinguishable from
//!   no linter.
//!
//! See docs/ANALYSIS.md for the lint catalogue, waiver syntax, and the
//! invariants each lint protects.

mod lints;
mod scan;

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use lints::{lint_file, Finding};
use scan::SourceFile;

/// The fixture corpus: (file name, virtual path it is linted under,
/// exact set of lints it must trip). Fixtures are compiled in via
/// `include_str!` so the self-test is independent of the working
/// directory. The virtual paths place each fixture in a serving-path
/// module so dir-scoped lints apply.
const FIXTURES: &[(&str, &str, &[&str], &str)] = &[
    (
        "hash_iteration.rs",
        "src/coordinator/hash_iteration.rs",
        &["determinism"],
        include_str!("../fixtures/hash_iteration.rs"),
    ),
    (
        "shard_local_hashmap.rs",
        "src/state/shard_local_hashmap.rs",
        &["determinism"],
        include_str!("../fixtures/shard_local_hashmap.rs"),
    ),
    (
        "unpaired_retain.rs",
        "src/state/unpaired_retain.rs",
        &["refcount"],
        include_str!("../fixtures/unpaired_retain.rs"),
    ),
    (
        "bare_unsafe.rs",
        "src/util/bare_unsafe.rs",
        &["unsafe"],
        include_str!("../fixtures/bare_unsafe.rs"),
    ),
    (
        "simd_no_safety.rs",
        "src/tensor/simd_no_safety.rs",
        &["unsafe"],
        include_str!("../fixtures/simd_no_safety.rs"),
    ),
    (
        "hot_path_alloc.rs",
        "src/tensor/hot_path_alloc.rs",
        &["hot_alloc"],
        include_str!("../fixtures/hot_path_alloc.rs"),
    ),
    (
        "span_emit_alloc.rs",
        "src/obs/span_emit_alloc.rs",
        &["hot_alloc"],
        include_str!("../fixtures/span_emit_alloc.rs"),
    ),
    // A reasonless waiver is flagged itself AND fails to suppress.
    (
        "bad_waiver.rs",
        "src/state/bad_waiver.rs",
        &["determinism", "waiver"],
        include_str!("../fixtures/bad_waiver.rs"),
    ),
];

/// The all-waivers fixture: every lint's trigger present, every one
/// covered by a well-formed waiver (or SAFETY contract) — must be clean.
const CLEAN_FIXTURE: (&str, &str) =
    ("src/state/clean_waivers.rs", include_str!("../fixtures/clean_waivers.rs"));

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") if args.iter().any(|a| a == "--self-test") => {
            if self_test() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Some("lint") => match lint_tree(&crate_src_root()) {
            Ok(0) => ExitCode::SUCCESS,
            Ok(_) => ExitCode::FAILURE,
            Err(e) => {
                eprintln!("xtask: {e}");
                ExitCode::FAILURE
            }
        },
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [--self-test]");
            eprintln!("lints: determinism | refcount | unsafe | hot_alloc (docs/ANALYSIS.md)");
            ExitCode::FAILURE
        }
    }
}

/// The `loglinear` crate root (parent of the xtask manifest dir).
fn crate_src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask sits inside the workspace").into()
}

/// Lint every `.rs` file under `<root>/src`; returns the finding count.
fn lint_tree(root: &Path) -> std::io::Result<usize> {
    let mut rels = Vec::new();
    collect_rs_files(&root.join("src"), "src/", &mut rels)?;
    let mut findings: Vec<Finding> = Vec::new();
    for rel in &rels {
        findings.extend(lint_file(&SourceFile::load(root, rel)?));
    }
    findings.sort_by(|a, b| (&a.rel, a.line, a.lint).cmp(&(&b.rel, b.line, b.lint)));
    for f in &findings {
        println!("{f}");
    }
    println!(
        "xtask lint: {} file(s), {} finding(s){}",
        rels.len(),
        findings.len(),
        if findings.is_empty() { " — clean" } else { "" }
    );
    Ok(findings.len())
}

/// Recursive sorted walk — sorted so output order is reproducible.
fn collect_rs_files(dir: &Path, prefix: &str, out: &mut Vec<String>) -> std::io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let name = match e.file_name().into_string() {
            Ok(n) => n,
            Err(_) => continue,
        };
        let rel = format!("{prefix}{name}");
        if e.file_type()?.is_dir() {
            collect_rs_files(&e.path(), &format!("{rel}/"), out)?;
        } else if name.ends_with(".rs") {
            out.push(rel);
        }
    }
    Ok(())
}

/// Run the fixture corpus; prints a verdict per fixture.
fn self_test() -> bool {
    let mut ok = true;
    let mut fired: Vec<&str> = Vec::new();
    for (name, rel, expected, src) in FIXTURES {
        let findings = lint_file(&SourceFile::parse(rel, src));
        let mut got: Vec<&str> = findings.iter().map(|f| f.lint).collect();
        got.sort_unstable();
        got.dedup();
        fired.extend(&got);
        let mut want = expected.to_vec();
        want.sort_unstable();
        if findings.is_empty() {
            ok = false;
            eprintln!("self-test FAIL {name}: expected {want:?} to fire, got nothing");
        } else if got != want {
            ok = false;
            eprintln!("self-test FAIL {name}: expected exactly {want:?}, got {got:?}:");
            for f in &findings {
                eprintln!("    {f}");
            }
        } else {
            println!("self-test ok   {name}: trips exactly {want:?}");
        }
    }
    let (clean_rel, clean_src) = CLEAN_FIXTURE;
    let findings = lint_file(&SourceFile::parse(clean_rel, clean_src));
    if findings.is_empty() {
        println!("self-test ok   clean_waivers.rs: all waivers honored, zero findings");
    } else {
        ok = false;
        eprintln!("self-test FAIL clean_waivers.rs: expected clean, got:");
        for f in &findings {
            eprintln!("    {f}");
        }
    }
    for lint in lints::LINT_NAMES {
        if !fired.contains(lint) {
            ok = false;
            eprintln!("self-test FAIL: no fixture exercises lint `{lint}`");
        }
    }
    ok
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance criterion in executable form: every lint category
    /// has a fixture proving it fires, and waivers are honored.
    #[test]
    fn fixture_corpus_self_test_passes() {
        assert!(self_test());
    }

    /// The real tree must lint clean — zero unwaivered findings. This is
    /// the same check CI runs via `cargo run -p xtask -- lint`, kept as
    /// a test so plain `cargo test` catches regressions too.
    #[test]
    fn real_tree_lints_clean() {
        let n = lint_tree(&crate_src_root()).expect("scan rust/src");
        assert_eq!(n, 0, "unwaivered lint findings in the tree (run `cargo run -p xtask -- lint`)");
    }
}
