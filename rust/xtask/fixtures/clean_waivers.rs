//! Clean fixture: every lint's trigger present, every one covered by a
//! well-formed waiver or `SAFETY:` contract (linted under `src/state/`).
//! The self-test asserts zero findings here — proof that the documented
//! escape hatches actually work, so a waiver is never worked around by
//! restructuring code to dodge the scanner.

use std::collections::HashMap; // xtask: allow(determinism): size bookkeeping only, never iterated

pub struct Pool {
    refs: Vec<u32>,
}

pub struct BlockId(pub usize);

impl Pool {
    pub fn retain(&mut self, id: &BlockId) {
        self.refs[id.0] += 1;
    }
}

pub fn count(sizes: &HashMap<u64, usize>) -> usize { // xtask: allow(determinism): .len() only
    sizes.len()
}

/// Ownership transfer: the cache entry owns the new reference and the
/// eviction path releases it.
pub fn adopt_into_cache(pool: &mut Pool, id: &BlockId) {
    // xtask: allow(refcount): reference owned by the cache entry; evict_lru releases it
    pool.retain(id);
}

pub fn read_first(xs: &[f32]) -> f32 {
    debug_assert!(!xs.is_empty());
    // SAFETY: callers uphold `!xs.is_empty()` (asserted above in debug
    // builds), so index 0 is in bounds.
    unsafe { *xs.get_unchecked(0) }
}

// xtask: deny_alloc
pub fn decode_step(out: &mut [f32], xs: &[f32], scratch: &mut Vec<f32>) {
    if scratch.is_empty() {
        // xtask: allow(hot_alloc): one-time warm-up snapshot, amortized to zero per token
        *scratch = xs.to_vec();
    }
    for (o, x) in out.iter_mut().zip(xs.iter()) {
        *o = *x;
    }
}
