//! Known-bad fixture: a waiver with no justification (linted under
//! `src/state/`). Reasonless waivers must (a) be flagged by the `waiver`
//! lint and (b) fail to suppress the underlying finding — otherwise
//! `xtask: allow(...)` becomes a magic incantation instead of a
//! documented exemption.

use std::collections::HashMap; // xtask: allow(determinism)

pub fn count(m: &HashMap<u64, f32>) -> usize {
    m.len()
}
