//! Known-bad fixture: allocation inside a span-emission path marked
//! `xtask: deny_alloc` (linted under `src/obs/`). Span emission runs on
//! every traced GEMM and token step; a `format!`/`Vec::new` there makes
//! the recorder's overhead scale with the workload it is measuring —
//! the zero-alloc ring design exists precisely to prevent that.

// xtask: deny_alloc
pub fn emit_span(cat: u8, payload: u64, sink: &mut Vec<(u8, String)>) {
    let label = format!("span cat={cat} payload={payload}");
    let mut batch = Vec::new();
    batch.push((cat, label.clone()));
    sink.extend(batch);
}

/// Unmarked sibling — must NOT fire (export/drain paths run once per
/// trace dump and may allocate freely).
pub fn export_span(cat: u8, payload: u64) -> String {
    format!("cat={cat} payload={payload}")
}
