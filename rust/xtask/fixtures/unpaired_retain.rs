//! Known-bad fixture: a pool reference taken via `retain()` with no
//! `release()` in the same function and no ownership-transfer waiver
//! (linted under `src/state/`). Leaked refcounts are exactly how the
//! copy-on-write pool quietly fills up: the block is never freed, the
//! admission control back-pressures, and nothing points at the culprit.

pub struct Pool {
    refs: Vec<u32>,
}

pub struct BlockId(pub usize);

impl Pool {
    pub fn retain(&mut self, id: &BlockId) {
        self.refs[id.0] += 1;
    }

    pub fn release(&mut self, id: &BlockId) {
        self.refs[id.0] -= 1;
    }
}

/// Takes a second owner on `id` and drops it on the floor.
pub fn leak_a_ref(pool: &mut Pool, id: &BlockId) {
    pool.retain(id);
}

/// Properly paired — must NOT fire.
pub fn borrow_briefly(pool: &mut Pool, id: &BlockId) {
    pool.retain(id);
    pool.release(id);
}

/// `Vec::retain` with a predicate — must NOT fire either.
pub fn prune(live: &mut Vec<u32>) {
    live.retain(|&x| x != 0);
}
