//! Known-bad fixture: a `HashMap` tracking shard-local blocks in a
//! state-path module (linted under `src/state/`). The lint must fire on
//! every `HashMap` mention in code — the `use` line, the field, and the
//! iteration below — and on nothing else.
//!
//! This is the sharding-specific shape of the determinism bug class:
//! `BlockId`s are shard-local (the same id names different blocks in
//! different shards), so per-shard accounting is tempting to hash — but
//! draining shards in hash-iteration order would reorder block release
//! and job dispatch between two identical runs, and the differential
//! trace harness could no longer promise bit-exact replays at every
//! shard count. Per-shard `Vec`s indexed by shard id (what
//! `ShardedStatePool` actually does) or a `BTreeMap` keep the order
//! deterministic.

use std::collections::HashMap;

pub struct ShardBlockIndex {
    /// blocks currently charged to each shard — nondeterministic to walk
    pub per_shard: HashMap<usize, Vec<usize>>,
}

impl ShardBlockIndex {
    pub fn drain_order(&self) -> Vec<(usize, usize)> {
        let mut order = Vec::new();
        for (&shard, blocks) in self.per_shard.iter() {
            for &b in blocks {
                order.push((shard, b));
            }
        }
        order
    }
}
