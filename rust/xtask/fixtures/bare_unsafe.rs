//! Known-bad fixture: an `unsafe` block with no `// SAFETY:` contract
//! (linted under `src/util/`). This is the exact shape of the thread
//! pool's lifetime erasure — a transmute whose soundness rests on a
//! completion barrier the code itself cannot express — which is why a
//! bare one is never acceptable: the contract lives only in the comment.

/// Erases the job's borrow lifetime with no stated justification.
pub fn erase<'env>(
    job: Box<dyn FnOnce() + Send + 'env>,
) -> Box<dyn FnOnce() + Send + 'static> {
    unsafe { std::mem::transmute(job) }
}

/// With the contract spelled out — must NOT fire.
pub fn erase_documented<'env>(
    job: Box<dyn FnOnce() + Send + 'env>,
) -> Box<dyn FnOnce() + Send + 'static> {
    // SAFETY: the caller guarantees the erased job is joined before
    // anything it borrows can be dropped (completion barrier).
    unsafe { std::mem::transmute(job) }
}
