//! Known-bad fixture: SIMD intrinsic calls inside `unsafe` with no
//! `// SAFETY:` contract (linted under `src/tensor/`). This is the
//! exact shape of the AVX2 microkernels in `tensor/simd.rs` — every
//! `target_feature(enable = ...)` call site's soundness rests on the
//! runtime `is_x86_feature_detected!` gate, which only a comment can
//! tie to the call — so a bare intrinsic block is never acceptable.

/// Loads eight lanes with no stated detection contract.
#[cfg(target_arch = "x86_64")]
pub fn sum8_undocumented(x: &[f32; 8]) -> f32 {
    unsafe {
        use std::arch::x86_64::*;
        let v = _mm256_loadu_ps(x.as_ptr());
        let mut out = [0.0f32; 8];
        _mm256_storeu_ps(out.as_mut_ptr(), v);
        out.iter().sum()
    }
}

/// With the detection contract spelled out — must NOT fire.
#[cfg(target_arch = "x86_64")]
pub fn sum8_documented(x: &[f32; 8]) -> f32 {
    // SAFETY: only reached behind `is_x86_feature_detected!("avx2")`;
    // the loads/stores cover exactly the 8-float arrays passed in.
    unsafe {
        use std::arch::x86_64::*;
        let v = _mm256_loadu_ps(x.as_ptr());
        let mut out = [0.0f32; 8];
        _mm256_storeu_ps(out.as_mut_ptr(), v);
        out.iter().sum()
    }
}
