//! Known-bad fixture: `HashMap` iteration order reaching dispatch order
//! in a serving-path module (linted under `src/coordinator/`). The lint
//! must fire on every `HashMap`/`HashSet` mention in code — the `use`
//! line and both signatures below.
//!
//! This is the bug class the determinism lint exists for: the batch here
//! would be dispatched in randomized hash order, so two identical runs
//! produce different GEMM accumulation orders and the differential trace
//! harness can no longer promise bit-exact replays.

use std::collections::{HashMap, HashSet};

pub fn dispatch_order(pending: &HashMap<u64, f32>) -> Vec<u64> {
    let mut order = Vec::new();
    for (&seq_id, _) in pending.iter() {
        order.push(seq_id);
    }
    order
}

pub fn active_set(order: &[u64]) -> HashSet<u64> {
    order.iter().copied().collect()
}
