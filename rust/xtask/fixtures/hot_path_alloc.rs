//! Known-bad fixture: allocations inside a function marked
//! `xtask: deny_alloc` (linted under `src/tensor/`). The decode hot path
//! runs once per generated token per sequence; a `Vec::new`/`to_vec`
//! there turns the steady-state loop into an allocator benchmark and
//! wrecks the latency tail the workspace-reuse design exists to protect.

// xtask: deny_alloc
pub fn decode_step(xs: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    let snapshot = xs.to_vec();
    out.extend_from_slice(&snapshot);
    out.clone()
}

/// Unmarked sibling doing the same thing — must NOT fire (the lint is
/// opt-in by marker; cold paths may allocate freely).
pub fn cold_setup(xs: &[f32]) -> Vec<f32> {
    xs.to_vec()
}
